//! The generation engine: prefill → prune → masked decode, exposed as
//! step-level sequence sessions. This is the request hot path — python
//! never runs here.
//!
//! The public surface is built from three primitives:
//!
//! * [`Sequence`] — one in-flight generation: prompt tokens, position,
//!   [`PagedKvCache`], [`ScoreBuffer`], sampler, per-sequence
//!   [`SamplingParams`] and pruning configuration, plus its host-side KV
//!   copy so it can join/leave decode groups between steps.
//! * [`Engine::prefill`] — run the prefill bucket for one sequence, apply
//!   the policy's prefill pruning, sample the first token.
//! * [`Engine::decode_step`] — advance any set of live sequences by one
//!   token together (they share one decode-bucket execution), emitting
//!   [`StepEvent`]s (token, eviction count, done reason).
//!
//! [`Engine::generate`] / [`Engine::generate_batch`] are thin loops over
//! these primitives; the continuous batcher drives the same primitives but
//! admits and removes sequences between steps (see batcher.rs).
//!
//! The engine is backend-generic: it only sees the [`Runtime`] facade and
//! opaque [`Buffer`]s, so the same code path drives the hermetic reference
//! backend and the PJRT artifacts. Data movement per decode step (see
//! docs/ARCHITECTURE.md): the group KV cache is *backend-resident* behind a
//! [`DecodeGroup`] handle. A sequence pays one full-slot scatter when it
//! joins a slot; after that a steady-state step uploads nothing but the
//! token/pos scalars, the backend writes the new KV row in place, and the
//! engine fetches only that `[L, H, d_head]` row back into the sequence's
//! host snapshot (`O(L·H·d_head)` per sequence per token instead of the
//! old `O(L·H·t_max·d_head)` repack round-trip). The keep-mask is
//! re-uploaded per slot only when `PagedKvCache` reports evictions.
//!
//! ```no_run
//! use std::sync::Arc;
//! use kvzap::coordinator::{Engine, SamplingParams};
//! use kvzap::policies::PolicySpec;
//! use kvzap::runtime::Runtime;
//!
//! let engine = Engine::new(Arc::new(Runtime::reference()));
//! let policy = PolicySpec::parse("kvzap_mlp:-4").unwrap().build(engine.window());
//! // step-level session: prefill once, then step until done
//! let mut seq = engine.sequence(1, "KEY = 90210. Q KEY\nA ", SamplingParams::greedy(8));
//! engine.prefill(&mut seq, policy.as_ref()).unwrap();
//! let mut group = engine.decode_group();
//! while !seq.is_done() {
//!     engine.decode_step(&mut group, &mut [&mut seq]).unwrap();
//! }
//! let result = engine.finish(&seq);
//! println!("{} (compression {:.2})", result.text, result.compression);
//! ```

#![warn(missing_docs)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::sampler::{Sampler, SamplingParams};
use crate::kvcache::{KvPools, PagedKvCache, TierConfig};
use crate::metrics::EngineMetrics;
use crate::policies::{PrefillView, PrunePolicy, ScoreBuffer, Stat};
use crate::runtime::kernels::{quant_roundtrip, QuantBits};
use crate::runtime::{Arg, KvHandle, Runtime, Tensor};
use crate::workload::ByteTokenizer;

/// Global sequence-identity counter: slot residency is tracked by this
/// nonce, not the caller-chosen `Sequence::id`, so id reuse across
/// requests can never alias a stale resident slot.
static NEXT_UID: AtomicU64 = AtomicU64::new(1);

/// The generation engine: owns the runtime handle, tokenizer and metrics,
/// and exposes the step-level session API ([`Engine::sequence`] →
/// [`Engine::prefill`] → [`Engine::decode_step`]) plus the
/// [`Engine::generate`]/[`Engine::generate_batch`] convenience loops. See
/// the module docs for a full session example.
pub struct Engine {
    /// Execution runtime (reference or PJRT backend behind the facade).
    pub rt: Arc<Runtime>,
    /// Byte-level tokenizer shared by every request.
    pub tok: ByteTokenizer,
    /// Rolling latency/throughput/compression histograms.
    pub metrics: EngineMetrics,
    /// Engine-level KV admission pools (None = uncharged, the default):
    /// every cache this engine creates or installs adopts these, so
    /// resident blocks and demoted side bytes across all live sequences
    /// draw from one shared budget. See [`Engine::set_kv_pools`].
    kv_pools: Mutex<Option<KvPools>>,
}

/// -log softmax(logits)[target] in nats.
fn nll_of(logits: &[f32], target: i32) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&x| ((x as f64) - m).exp()).sum::<f64>().ln() + m;
    lse - logits[target as usize] as f64
}

/// Everything one finished generation produced ([`Engine::finish`]).
#[derive(Debug, Clone)]
pub struct GenResult {
    /// Decoded output text (byte-level tokens concatenated).
    pub text: String,
    /// Prompt length in tokens, BOS included.
    pub prompt_len: usize,
    /// Number of accepted output tokens.
    pub tokens_out: usize,
    /// Removed fraction of the KV cache at end of generation (the paper's
    /// "compression ratio (removed fraction)", Table 2).
    pub compression: f64,
    /// Wall-clock µs spent in the prefill execution.
    pub prefill_us: u64,
    /// Wall-clock µs spent in the KVzip oracle double pass (0 unless the
    /// policy needs it).
    pub oracle_us: u64,
    /// Wall-clock µs spent in decode steps (shared steps count fully).
    pub decode_us: u64,
    /// Wall-clock µs spent scoring/evicting inside the policy.
    pub policy_us: u64,
    /// KV pairs evicted during decode (Algorithm 1's delayed eviction).
    pub decode_evictions: usize,
    /// KV pairs demoted to the quantized side tier during decode.
    pub decode_demotions: usize,
    /// Demoted KV pairs rehydrated back to residency during decode.
    pub decode_rehydrations: usize,
    /// Demoted rows attended in place (quantized, no rehydrate) during
    /// decode, summed over steps.
    pub decode_quant_attends: usize,
}

/// Why a sequence stopped generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DoneReason {
    /// The model emitted a stop token (EOS/PAD, or newline for
    /// newline-terminated task grammars).
    Stop,
    /// The per-sequence `max_new` token budget was reached.
    MaxTokens,
    /// The KV cache ran out of positions (`t_max`).
    CacheFull,
    /// The request was cancelled mid-generation.
    Cancelled,
}

impl DoneReason {
    /// Wire name of the reason (the v2 protocol's `"reason"` field).
    pub fn as_str(self) -> &'static str {
        match self {
            DoneReason::Stop => "stop",
            DoneReason::MaxTokens => "max_tokens",
            DoneReason::CacheFull => "cache_full",
            DoneReason::Cancelled => "cancelled",
        }
    }
}

/// What one engine step produced for one sequence.
#[derive(Debug, Clone)]
pub enum StepEvent {
    /// A new token was accepted into the sequence. `text` is its decoded
    /// byte (the tokenizer is byte-level); `evicted` counts KV pairs the
    /// threshold policy removed at this step (Algorithm 1's delayed
    /// eviction), `demoted` the pairs it quantized into the side tier
    /// instead, and `rehydrated` the demoted pairs brought back to
    /// residency (score rebound / window re-entry).
    /// `kv_up_bytes`/`kv_down_bytes` account this sequence's KV
    /// traffic for the step: a join costs one full-slot scatter (+ mask),
    /// an eviction step one mask refresh, and a steady-state step only the
    /// decoded-row fetch. Demotions and rehydrations are device-local and
    /// contribute no transfer bytes.
    Token {
        id: u64,
        token: i32,
        text: String,
        evicted: usize,
        demoted: usize,
        rehydrated: usize,
        kv_up_bytes: u64,
        kv_down_bytes: u64,
    },
    /// The sequence finished; no more events will follow for `id`.
    Done { id: u64, reason: DoneReason },
}

/// One in-flight generation: everything the engine needs to advance a
/// request one token at a time. Create with [`Engine::sequence`], run
/// [`Engine::prefill`] once, then pass to [`Engine::decode_step`] together
/// with any other live sequences until [`Sequence::is_done`].
pub struct Sequence {
    /// Caller-chosen request id, echoed on every [`StepEvent`].
    pub id: u64,
    /// Process-unique identity nonce (see [`NEXT_UID`]); slot residency in
    /// a [`DecodeGroup`] is keyed by this.
    uid: u64,
    /// Per-sequence sampling parameters (greedy/top-k, budget, stops).
    pub sp: SamplingParams,
    /// Human-readable policy label (set at prefill; for logs/metrics).
    pub policy_name: String,
    /// Prompt token ids (BOS + bytes, truncated to the max prefill bucket).
    toks: Vec<i32>,
    /// Accepted generated tokens.
    pub generated: Vec<i32>,
    /// Next cache position to be written by decode (== tokens fed so far).
    pos: usize,
    /// Token to feed at the next decode step.
    cur: i32,
    cache: PagedKvCache,
    sbuf: ScoreBuffer,
    /// Decode-time eviction threshold (None: no decode pruning).
    tau: Option<f32>,
    /// Which surrogate drives decode-time scores.
    dstat: Stat,
    /// Optional agreement gate `(stat, gate_tau)`: decode eviction also
    /// requires the gate stat below `gate_tau` (Fast-KVzip). The sequence
    /// then buffers margins `max(score - tau, gate - gate_tau)` against an
    /// effective threshold of 0.
    gate: Option<(Stat, f32)>,
    /// Demotion floor in the *buffered-score space* (raw stat, or gated
    /// margin when `gate` is set): window-exiting scores in `[floor, τ)`
    /// demote to the quantized side tier instead of dropping. `None`
    /// disables the tier for this sequence (drop-only decode).
    floor: Option<f32>,
    /// Per-head ledger of demoted positions and their buffered-space
    /// scores, indexed `l * heads + h` — the rehydration scan compares
    /// these against each step's incoming score (rebound rule) and the
    /// window start (re-entry backstop).
    demoted_scores: Vec<Vec<(usize, f32)>>,
    sampler: Sampler,
    /// Host snapshot of this sequence's KV rows, `[L, H, t_max, D]` — lets
    /// the sequence join a decode group in any slot at any step. Written
    /// once at prefill and kept fresh by the per-step decoded-row fetch,
    /// so leaving a group needs no bulk gather.
    k: Vec<f32>,
    v: Vec<f32>,
    done: Option<DoneReason>,
    prefilled: bool,
    /// KV pairs evicted during decode so far.
    pub decode_evictions: usize,
    /// KV pairs demoted to the quantized side tier during decode so far.
    pub decode_demotions: usize,
    /// Demoted pairs rehydrated back to residency during decode so far.
    pub decode_rehydrations: usize,
    /// Demoted rows attended in place by the quantized decode path so far
    /// (each step counts every side entry it read).
    pub decode_quant_attends: usize,
    /// Wall-clock µs spent in this sequence's prefill execution.
    pub prefill_us: u64,
    /// Wall-clock µs spent in the KVzip oracle pass (0 unless needed).
    pub oracle_us: u64,
    /// Wall-clock µs of decode steps this sequence participated in.
    pub decode_us: u64,
    /// Wall-clock µs spent scoring/evicting inside the policy.
    pub policy_us: u64,
}

impl Sequence {
    /// Whether the sequence finished (see [`Sequence::done_reason`]).
    pub fn is_done(&self) -> bool {
        self.done.is_some()
    }

    /// Why the sequence finished, if it has.
    pub fn done_reason(&self) -> Option<DoneReason> {
        self.done
    }

    /// Prompt length in tokens, BOS included.
    pub fn prompt_len(&self) -> usize {
        self.toks.len()
    }

    /// Number of accepted output tokens so far.
    pub fn tokens_out(&self) -> usize {
        self.generated.len()
    }

    /// Removed fraction of this sequence's KV cache so far.
    pub fn compression(&self) -> f64 {
        self.cache.stats().compression()
    }

    /// Full cache accounting (kept/filled/blocks) for this sequence.
    pub fn cache_stats(&self) -> crate::kvcache::CacheStats {
        self.cache.stats()
    }

    /// Process-unique identity nonce; slot residency in a [`DecodeGroup`]
    /// is keyed by this (see [`DecodeGroup::resident_uids`]).
    pub fn uid(&self) -> u64 {
        self.uid
    }

    /// Next cache position to be written by decode (== tokens fed so far).
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Read-only view of this sequence's paged KV cache bookkeeping — the
    /// per-head kept bitsets and the eviction dirty flag. The simulation
    /// harness uses this to check accounting invariants after every step.
    pub fn cache(&self) -> &PagedKvCache {
        &self.cache
    }

    /// Demoted positions the engine's rehydration ledger tracks, summed
    /// over heads. Must always equal `cache().stats().demoted` — the
    /// simulation harness checks this tier-conservation invariant.
    pub fn tracked_demoted(&self) -> usize {
        self.demoted_scores.iter().map(|v| v.len()).sum()
    }

    /// Mark the sequence as cancelled; it will be skipped by subsequent
    /// decode steps. No-op when the sequence already finished.
    pub fn cancel(&mut self) {
        if self.done.is_none() {
            self.done = Some(DoneReason::Cancelled);
        }
    }
}

/// A reusable snapshot of one prefilled sequence, captured just before
/// first-token sampling ([`Engine::prefill_with_snapshot`]): the pruned
/// host KV, paged-cache bookkeeping, decode score window, tier ledger and
/// the prefill logits row. The router's prefix cache stores one per unique
/// (prompt, policy) and installs clones into joining sequences
/// ([`Engine::prefill_from_snapshot`]), so requests sharing a prompt
/// prefix skip the prefill execution entirely.
pub struct PrefillSnapshot {
    policy_name: String,
    prompt_len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    cache: PagedKvCache,
    sbuf: ScoreBuffer,
    tau: Option<f32>,
    dstat: Stat,
    gate: Option<(Stat, f32)>,
    floor: Option<f32>,
    demoted_scores: Vec<Vec<(usize, f32)>>,
    logits0: Vec<f32>,
}

impl PrefillSnapshot {
    /// Prompt length in tokens (BOS included) the snapshot was taken at.
    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// Approximate host bytes the snapshot pins (KV copy + logits row).
    pub fn approx_bytes(&self) -> usize {
        4 * (self.k.len() + self.v.len() + self.logits0.len())
    }

    /// Test-only stand-in with a chosen [`PrefillSnapshot::approx_bytes`]
    /// (`bytes` rounded down to a multiple of 4). Lets cache-eviction
    /// unit tests size entries exactly without running prefills.
    #[cfg(test)]
    pub(crate) fn test_stub(bytes: usize) -> PrefillSnapshot {
        PrefillSnapshot {
            policy_name: String::new(),
            prompt_len: 0,
            k: vec![0.0; bytes / 4],
            v: vec![],
            cache: PagedKvCache::new_tiered(
                1,
                1,
                1,
                TierConfig { d_head: 1, bits: QuantBits::Int8, group: 1 },
            ),
            sbuf: ScoreBuffer::new(1, 1, 1),
            tau: None,
            dstat: Stat::ScoreMlp,
            gate: None,
            floor: None,
            demoted_scores: vec![],
            logits0: vec![],
        }
    }
}

/// Round-trip one position's K and V rows of a `[L, H, t_max, D]` host
/// snapshot through the tier's quantizer, in place. A demoted row must
/// read back exactly the lossy values the side tier stores, so a later
/// group-join scatter reproduces the backend's rehydrated state bitwise.
#[allow(clippy::too_many_arguments)]
fn roundtrip_snapshot_row(
    k: &mut [f32],
    v: &mut [f32],
    tier: TierConfig,
    heads: usize,
    t_max: usize,
    d_head: usize,
    l: usize,
    h: usize,
    pos: usize,
) {
    let at = (l * heads + h) * (t_max * d_head) + pos * d_head;
    quant_roundtrip(&mut k[at..at + d_head], tier.group, tier.bits);
    quant_roundtrip(&mut v[at..at + d_head], tier.group, tier.bits);
}

struct PrefillStats {
    score_lin: Tensor,
    score_mlp: Tensor,
    max_attn: Tensor,
    plus_attn: Tensor,
    cum_attn: Tensor,
    win_attn: Tensor,
    vnorm: Tensor,
    knorm: Tensor,
}

impl PrefillStats {
    fn view<'a>(
        &'a self,
        b: usize,
        oracle: Option<&'a (Tensor, Tensor)>,
    ) -> PrefillView<'a> {
        PrefillView {
            b,
            score_lin: &self.score_lin,
            score_mlp: &self.score_mlp,
            max_attn: &self.max_attn,
            plus_attn: &self.plus_attn,
            cum_attn: &self.cum_attn,
            win_attn: &self.win_attn,
            vnorm: &self.vnorm,
            knorm: &self.knorm,
            oracle_s: oracle.map(|o| &o.0),
            oracle_s_plus: oracle.map(|o| &o.1),
        }
    }
}

/// A persistent decode-group session: owns the backend-resident KV cache
/// handle and tracks which sequence occupies each slot. Create one with
/// [`Engine::decode_group`] and pass it to every [`Engine::decode_step`]
/// of the same scheduling loop; membership changes (join/leave between
/// steps) are reconciled against it — a sequence pays a full-slot scatter
/// only when it (re)joins, and the group cache is reallocated only when
/// the decode bucket (slot capacity) changes.
pub struct DecodeGroup {
    rt: Arc<Runtime>,
    handle: Option<KvHandle>,
    /// Resident sequence uid per slot (0 = vacant).
    slots: Vec<u64>,
}

impl DecodeGroup {
    /// Current slot capacity (the resident decode bucket's batch size).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Resident sequence uid per slot (0 = vacant), in slot order. A
    /// finished sequence keeps its slot until a later step vacates it, so
    /// entries here can name sequences that already completed.
    pub fn resident_uids(&self) -> &[u64] {
        &self.slots
    }

    /// The backend cache handle, if one is allocated (crate-internal: the
    /// simulation harness uses it to inject accounting faults).
    pub(crate) fn kv_handle(&self) -> Option<&KvHandle> {
        self.handle.as_ref()
    }

    /// Free the backend cache; the next step reallocates and re-scatters.
    pub fn reset(&mut self) {
        if let Some(h) = self.handle.take() {
            self.rt.kv_free(&h);
        }
        self.slots.clear();
    }
}

impl Drop for DecodeGroup {
    fn drop(&mut self) {
        self.reset();
    }
}

impl Engine {
    /// An engine over `rt` with fresh metrics (cheap; the weights and
    /// backend live inside the runtime).
    pub fn new(rt: Arc<Runtime>) -> Engine {
        Engine {
            rt,
            tok: ByteTokenizer::default(),
            metrics: EngineMetrics::default(),
            kv_pools: Mutex::new(None),
        }
    }

    /// Install (or clear) the engine-level KV admission pools. Affects
    /// caches created *after* the call — [`Engine::sequence`], the
    /// prefill-time tier rebuild, and snapshot installs all adopt the
    /// configured pools; already-live sequences keep whatever they had.
    /// With [`KvPools::Unified`] the whole engine's KV footprint (resident
    /// blocks at f32 width + demoted quantized bytes) is bounded by one
    /// byte budget, and demotions refuse gracefully under pressure.
    pub fn set_kv_pools(&self, pools: Option<KvPools>) {
        *self.kv_pools.lock().unwrap() = pools;
    }

    /// The currently installed engine-level pools (see
    /// [`Engine::set_kv_pools`]).
    pub fn kv_pools(&self) -> Option<KvPools> {
        self.kv_pools.lock().unwrap().clone()
    }

    /// A fresh (empty) decode-group session for [`Engine::decode_step`].
    pub fn decode_group(&self) -> DecodeGroup {
        DecodeGroup { rt: self.rt.clone(), handle: None, slots: vec![] }
    }

    /// The policy sliding-window size `w` (manifest-level constant).
    pub fn window(&self) -> usize {
        self.rt.manifest.window
    }

    /// Largest prompt (in tokens incl. BOS) the artifacts can prefill.
    pub fn max_prompt(&self) -> usize {
        *self.rt.manifest.buckets.prefill_t.iter().max().unwrap()
    }

    /// Tier configuration for the quantized demotion side pool every
    /// engine cache carries: int8, group-8 over the model head dim. The
    /// tier stays empty unless a two-threshold policy demotes into it.
    pub fn tier_config(&self) -> TierConfig {
        self.tier_config_bits(QuantBits::Int8)
    }

    /// Tier configuration at a caller-chosen code width. Prefill swaps a
    /// sequence's cache to the policy's [`PrunePolicy::tier_bits`] width
    /// through this before any fill/prune bookkeeping lands in it.
    pub fn tier_config_bits(&self, bits: QuantBits) -> TierConfig {
        TierConfig { d_head: self.rt.manifest.model.d_head, bits, group: 8 }
    }

    /// Create a fresh (not yet prefilled) sequence for `prompt`.
    pub fn sequence(&self, id: u64, prompt: &str, sp: SamplingParams) -> Sequence {
        let man = &self.rt.manifest;
        let (layers, heads, t_max) =
            (man.model.n_layers, man.model.n_kv_heads, man.model.t_max);
        let seed = sp.seed;
        let mut cache = PagedKvCache::new_tiered(layers, heads, t_max, self.tier_config());
        if let Some(pools) = self.kv_pools() {
            let ok = cache.adopt_pools(&pools);
            debug_assert!(ok, "adopting pools into an empty cache cannot fail");
        }
        Sequence {
            id,
            uid: NEXT_UID.fetch_add(1, Ordering::Relaxed),
            toks: self.tok.encode(prompt, self.max_prompt()),
            generated: vec![],
            pos: 0,
            cur: self.tok.pad as i32,
            cache,
            sbuf: ScoreBuffer::new(self.window(), layers, heads),
            tau: None,
            dstat: Stat::ScoreMlp,
            gate: None,
            floor: None,
            demoted_scores: vec![Vec::new(); layers * heads],
            sampler: Sampler::new(seed),
            sp,
            policy_name: String::new(),
            k: vec![],
            v: vec![],
            done: None,
            prefilled: false,
            decode_evictions: 0,
            decode_demotions: 0,
            decode_rehydrations: 0,
            decode_quant_attends: 0,
            prefill_us: 0,
            oracle_us: 0,
            decode_us: 0,
            policy_us: 0,
        }
    }

    /// Prefill one sequence: run the prefill bucket, apply `policy`'s
    /// prefill-time pruning, seed the decode score window, and sample the
    /// first token from the prefill logits. Returns the emitted events
    /// (first token, and possibly an immediate done).
    pub fn prefill(&self, seq: &mut Sequence, policy: &dyn PrunePolicy) -> Result<Vec<StepEvent>> {
        let logits0 = self.prefill_inner(seq, policy)?;
        Ok(self.first_token(seq, logits0.row(&[0])))
    }

    /// [`Engine::prefill`] that additionally captures a [`PrefillSnapshot`]
    /// of the post-prune sequence state. The snapshot is taken *before*
    /// the first token is sampled, so a sequence resumed from it replays
    /// the whole generation — its own per-request sampler draws the first
    /// token from the stored logits row. This is what the router's prefix
    /// cache stores on a miss.
    pub fn prefill_with_snapshot(
        &self,
        seq: &mut Sequence,
        policy: &dyn PrunePolicy,
    ) -> Result<(Vec<StepEvent>, PrefillSnapshot)> {
        let logits0 = self.prefill_inner(seq, policy)?;
        let snap = PrefillSnapshot {
            policy_name: seq.policy_name.clone(),
            prompt_len: seq.toks.len(),
            k: seq.k.clone(),
            v: seq.v.clone(),
            cache: seq.cache.clone(),
            sbuf: seq.sbuf.clone(),
            tau: seq.tau,
            dstat: seq.dstat,
            gate: seq.gate,
            floor: seq.floor,
            demoted_scores: seq.demoted_scores.clone(),
            logits0: logits0.row(&[0]).to_vec(),
        };
        Ok((self.first_token(seq, &snap.logits0), snap))
    }

    /// Install a cached [`PrefillSnapshot`] into a fresh sequence instead
    /// of running the prefill bucket (a prefix-cache hit). The sequence
    /// must carry the same prompt and policy the snapshot was taken from.
    /// Its own sampler draws the first token from the stored logits row,
    /// so the generation is bitwise identical to a cache-miss prefill;
    /// backend-side state is reproduced by the normal decode-step join
    /// path (full-slot scatter + mask + re-demotion of the tracked band),
    /// exactly as a leave/rejoin already does.
    ///
    /// When the engine carries [`Engine::set_kv_pools`] admission pools,
    /// the installed cache's holdings (resident blocks + demoted bytes)
    /// are charged against them up front; an exhausted pool refuses the
    /// install with an error instead of admitting unbounded bytes.
    pub fn prefill_from_snapshot(
        &self,
        seq: &mut Sequence,
        snap: &PrefillSnapshot,
    ) -> Result<Vec<StepEvent>> {
        assert!(!seq.prefilled, "sequence {} already prefilled", seq.id);
        debug_assert_eq!(seq.toks.len(), snap.prompt_len, "snapshot/prompt length mismatch");
        let mut cache = snap.cache.clone();
        if let Some(pools) = self.kv_pools() {
            if !cache.adopt_pools(&pools) {
                return Err(anyhow!(
                    "kv pool exhausted: snapshot install of {} bytes refused admission",
                    cache.charged_bytes()
                ));
            }
        }
        seq.k = snap.k.clone();
        seq.v = snap.v.clone();
        seq.cache = cache;
        seq.sbuf = snap.sbuf.clone();
        seq.tau = snap.tau;
        seq.dstat = snap.dstat;
        seq.gate = snap.gate;
        seq.floor = snap.floor;
        seq.demoted_scores = snap.demoted_scores.clone();
        seq.policy_name = snap.policy_name.clone();
        seq.prefilled = true;
        seq.pos = snap.prompt_len;
        Ok(self.first_token(seq, &snap.logits0))
    }

    /// The shared prefill body: everything up to (but not including) the
    /// first-token sample. Returns the prefill logits tensor.
    fn prefill_inner(&self, seq: &mut Sequence, policy: &dyn PrunePolicy) -> Result<Tensor> {
        assert!(!seq.prefilled, "sequence {} already prefilled", seq.id);
        let man = &self.rt.manifest;
        let n = seq.toks.len();
        let bucket = man
            .prefill_bucket(n, 1)
            .ok_or_else(|| anyhow!("no prefill bucket for len {n}"))?;
        let pf = self.rt.artifact(&bucket)?;
        let pt = pf.meta.t;
        let mut tok_flat = vec![self.tok.pad as i32; pt];
        tok_flat[..n].copy_from_slice(&seq.toks);
        let lens = [n as i32];

        let t0 = crate::util::now_micros();
        let outs =
            self.rt.exec(&pf, &[Arg::I32(&tok_flat, &[1, pt]), Arg::I32(&lens, &[1])])?;
        seq.prefill_us = crate::util::now_micros() - t0;
        self.metrics.prefill.lock().unwrap().record(seq.prefill_us);

        let fetch = |name: &str| -> Result<Tensor> {
            let i = pf.meta.output_index(name)?;
            self.rt.fetch_f32(&outs[i], &pf.meta.outputs[i].shape)
        };
        let logits0 = fetch("logits")?;
        let stats = PrefillStats {
            score_lin: fetch("score_lin")?,
            score_mlp: fetch("score_mlp")?,
            max_attn: fetch("max_attn")?,
            plus_attn: fetch("plus_attn")?,
            cum_attn: fetch("cum_attn")?,
            win_attn: fetch("win_attn")?,
            vnorm: fetch("vnorm")?,
            knorm: fetch("knorm")?,
        };
        seq.k = fetch("kcache")?.data;
        seq.v = fetch("vcache")?.data;

        // oracle double pass (KVzip / KVzip+ baselines only)
        let oracle = if policy.needs_oracle() {
            let t0 = crate::util::now_micros();
            let o = self.oracle_scores(&seq.toks)?;
            seq.oracle_us = crate::util::now_micros() - t0;
            self.metrics.oracle.lock().unwrap().record(seq.oracle_us);
            Some(o)
        } else {
            None
        };

        // the policy picks the side tier's code width: rebuild this
        // sequence's cache at that width before any fill/prune bookkeeping
        // lands in it (the default sequence cache is int8)
        let bits = policy.tier_bits();
        if seq.cache.tier().bits != bits {
            let mut cache = PagedKvCache::new_tiered(
                man.model.n_layers,
                man.model.n_kv_heads,
                man.model.t_max,
                self.tier_config_bits(bits),
            );
            if let Some(pools) = self.kv_pools() {
                let ok = cache.adopt_pools(&pools);
                debug_assert!(ok, "adopting pools into an empty cache cannot fail");
            }
            seq.cache = cache;
        }

        // prune after prefill + seed the decode score window
        let t0 = crate::util::now_micros();
        if !seq.cache.fill(n) {
            return Err(anyhow!("kv pool exhausted: prefill of {n} positions refused admission"));
        }
        policy.prefill_prune(&stats.view(0, oracle.as_ref()), n, &mut seq.cache);
        seq.tau = policy.decode_threshold();
        seq.dstat = policy.decode_stat();
        seq.gate = policy.decode_gate();
        if let Some(tau) = seq.tau {
            let view = stats.view(0, None);
            let dstat = seq.dstat;
            match seq.gate {
                None => {
                    seq.sbuf.seed_from_prefill(n, |l, h, pos| view.row(dstat, l, h)[pos]);
                }
                // gated sequences buffer margins: evict-iff-both-below
                // is exactly max(score - tau, gate - gate_tau) < 0
                Some((gstat, gtau)) => {
                    seq.sbuf.seed_from_prefill(n, |l, h, pos| {
                        (view.row(dstat, l, h)[pos] - tau)
                            .max(view.row(gstat, l, h)[pos] - gtau)
                    });
                }
            }
        }
        // two-threshold policies: express the demotion floor in the same
        // space the score buffer holds — the raw stat, or the gated margin
        // (compared against an effective threshold of 0, so the band
        // `[floor, τ)` maps to margins `[floor - τ, 0)`)
        seq.floor = match (policy.decode_floor(), seq.tau, seq.gate) {
            (Some(fl), Some(tau), Some(_)) => Some(fl - tau),
            (fl, _, _) => fl,
        };
        // prefill pruning may have demoted prompt positions: remember
        // their buffered-space scores for rebound rehydration, and
        // round-trip the host snapshot rows so a group join scatters
        // exactly the lossy values the quantized side tier stores
        if seq.cache.stats().demoted > 0 {
            let view = stats.view(0, None);
            let (dstat, tier) = (seq.dstat, seq.cache.tier());
            let (heads, t_max, d) =
                (man.model.n_kv_heads, man.model.t_max, man.model.d_head);
            for l in 0..man.model.n_layers {
                for h in 0..heads {
                    for p in seq.cache.demoted_positions(l, h) {
                        let s = view.row(dstat, l, h)[p];
                        let s = match (seq.gate, seq.tau) {
                            (Some((gstat, gtau)), Some(tau)) => {
                                (s - tau).max(view.row(gstat, l, h)[p] - gtau)
                            }
                            _ => s,
                        };
                        seq.demoted_scores[l * heads + h].push((p, s));
                        roundtrip_snapshot_row(
                            &mut seq.k, &mut seq.v, tier, heads, t_max, d, l, h, p,
                        );
                    }
                }
            }
        }
        seq.policy_us = crate::util::now_micros() - t0;
        seq.policy_name = policy.name();
        seq.prefilled = true;
        seq.pos = n;
        Ok(logits0)
    }

    /// Shared first-token tail: sample from the prefill logits row (fresh
    /// prefill or cached snapshot) and emit the token / immediate-done
    /// events.
    fn first_token(&self, seq: &mut Sequence, logits0: &[f32]) -> Vec<StepEvent> {
        let mut events = vec![];
        let t = seq.sampler.sample(logits0, &seq.sp);
        if self.tok.is_stop(t, seq.sp.stop_at_newline) {
            seq.done = Some(DoneReason::Stop);
            events.push(StepEvent::Done { id: seq.id, reason: DoneReason::Stop });
        } else {
            seq.generated.push(t);
            seq.cur = t;
            events.push(StepEvent::Token {
                id: seq.id,
                token: t,
                text: self.tok.decode(&[t]),
                evicted: 0,
                demoted: 0,
                rehydrated: 0,
                kv_up_bytes: 0,
                kv_down_bytes: 0,
            });
            if seq.generated.len() >= seq.sp.max_new {
                seq.done = Some(DoneReason::MaxTokens);
                events.push(StepEvent::Done { id: seq.id, reason: DoneReason::MaxTokens });
            }
        }
        events
    }

    /// Advance every live sequence in `seqs` by one decode step. The
    /// sequences share one decode-bucket execution (slot-batched) over
    /// `group`'s backend-resident KV cache; done or not-yet-prefilled
    /// sequences are skipped, so a scheduler can pass a stable set while
    /// membership changes between steps. A sequence absent from `seqs`
    /// vacates its slot (its host KV snapshot is already current) and
    /// re-scatters if it later rejoins. Demoted side-tier rows contribute
    /// to attention directly in quantized form
    /// ([`Runtime::exec_decode_resident_quant`]); the rehydration scan
    /// only *promotes* hot rows (score rebound / window re-entry), it is
    /// not required for a demoted row to be attendable. Returns the
    /// step's events in sequence order.
    pub fn decode_step(
        &self,
        group: &mut DecodeGroup,
        seqs: &mut [&mut Sequence],
    ) -> Result<Vec<StepEvent>> {
        let man = &self.rt.manifest;
        let (layers, heads, t_max, d_head) = (
            man.model.n_layers,
            man.model.n_kv_heads,
            man.model.t_max,
            man.model.d_head,
        );
        let mut events = vec![];
        // sequences that would overflow the cache stop here
        for seq in seqs.iter_mut() {
            if seq.prefilled && seq.done.is_none() && seq.pos >= t_max {
                seq.done = Some(DoneReason::CacheFull);
                events.push(StepEvent::Done { id: seq.id, reason: DoneReason::CacheFull });
            }
        }
        let active: Vec<usize> = (0..seqs.len())
            .filter(|&i| seqs[i].prefilled && seqs[i].done.is_none())
            .collect();
        if active.is_empty() {
            return Ok(events);
        }
        let nb = active.len();
        let bucket =
            man.decode_bucket(nb).ok_or_else(|| anyhow!("no decode bucket for {nb}"))?;
        let dec = self.rt.artifact(&bucket)?;
        let db = dec.meta.batch;

        let t0 = crate::util::now_micros();
        // ---- reconcile slot residency against the resident group --------
        // bucket change (group grew/shrunk past a capacity step): the old
        // allocation cannot be reused — free it and re-scatter everyone
        if group.handle.as_ref().map(|h| h.batch) != Some(db) {
            group.reset();
            group.handle = Some(self.rt.kv_alloc(db)?);
            group.slots = vec![0; db];
        }
        let handle = group.handle.as_ref().unwrap();
        let slots = &mut group.slots;
        // vacate slots whose occupant is not in this step's active set
        // (zero mask built lazily: steady-state steps never vacate)
        let mut zero_mask: Option<Vec<f32>> = None;
        for s in 0..db {
            if slots[s] != 0 && !active.iter().any(|&si| seqs[si].uid == slots[s]) {
                slots[s] = 0;
                let zm =
                    zero_mask.get_or_insert_with(|| vec![0.0f32; handle.mask_elems()]);
                self.rt.kv_write_mask(handle, s, zm)?;
                // side-tier entries bypass the resident mask on the
                // quantized decode path, so a vacated slot must purge them
                // too — a stale band must never be attended (or counted)
                // under the next occupant
                self.rt.kv_drop_slot(handle, s)?;
            }
        }
        // per-sequence KV transfer attribution for this step's events
        let mut kv_up = vec![0u64; seqs.len()];
        let mut kv_down = vec![0u64; seqs.len()];
        // residents keep their slot; newcomers scatter into free ones
        let mut slot_of = vec![usize::MAX; seqs.len()];
        for &si in &active {
            if let Some(s) = slots.iter().position(|&u| u == seqs[si].uid) {
                slot_of[si] = s;
            }
        }
        for &si in &active {
            let seq = &mut *seqs[si];
            if slot_of[si] != usize::MAX {
                // resident: refresh the mask only when evictions dirtied it
                if seq.cache.take_dirty() {
                    let m = seq.cache.mask_f32();
                    self.rt.kv_write_mask(handle, slot_of[si], &m)?;
                    kv_up[si] += 4 * m.len() as u64;
                }
                continue;
            }
            let s = slots.iter().position(|&u| u == 0).expect("free slot (db >= nb)");
            self.rt.kv_scatter(handle, s, &seq.k, &seq.v)?;
            let m = seq.cache.mask_f32();
            self.rt.kv_write_mask(handle, s, &m)?;
            seq.cache.take_dirty(); // the upload covered any pending change
            kv_up[si] += 4 * (seq.k.len() + seq.v.len() + m.len()) as u64;
            slots[s] = seq.uid;
            slot_of[si] = s;
            // the scatter purged this slot's side-tier entries on the
            // backend: re-demote every tracked position. The snapshot rows
            // were round-tripped at demotion time and quantization is
            // stable under re-encoding, so this reproduces the quantized
            // payloads bitwise (device-local, no transfer bytes).
            if seq.cache.stats().demoted > 0 {
                let tier = seq.cache.tier();
                let mut band = vec![];
                for l in 0..layers {
                    for h in 0..heads {
                        for p in seq.cache.demoted_positions(l, h) {
                            band.push((l, h, p));
                        }
                    }
                }
                self.rt.kv_demote_band(handle, s, &band, tier.bits, tier.group)?;
            }
        }

        // ---- one resident step over the whole group ---------------------
        let mut cur = vec![self.tok.pad as i32; db];
        let mut pos_i32 = vec![(t_max - 1) as i32; db];
        for &si in &active {
            cur[slot_of[si]] = seqs[si].cur;
            pos_i32[slot_of[si]] = seqs[si].pos as i32;
        }
        // demoted rows contribute to attention directly in quantized form
        // (dequantize-in-register on the backend); rehydration below is an
        // optimization that promotes hot rows, not a correctness gate
        let (outs, qstats) =
            self.rt.exec_decode_resident_quant(&dec, &cur, &pos_i32, handle)?;
        let q_rows: u64 = qstats.iter().map(|s| s.rows as u64).sum();
        let q_bytes: u64 = qstats.iter().map(|s| s.bytes as u64).sum();
        if q_rows > 0 || q_bytes > 0 {
            self.metrics.note_quant_attend(q_rows, q_bytes);
        }
        let fetch = |name: &str| -> Result<Tensor> {
            let oi = dec.meta.output_index(name)?; // manifest shape
            let ri = dec.meta.resident_output_index(name)?; // resident position
            self.rt.fetch_f32(&outs[ri], &dec.meta.outputs[oi].shape)
        };
        let logits = fetch("logits")?;
        // decode-time surrogate fetches: score_lin serves Stat::ScoreLin,
        // score_mlp serves everything else; a gated sequence may need both
        let is_lin = |st: Stat| st == Stat::ScoreLin;
        let need_lin = active.iter().any(|&i| {
            let s = &seqs[i];
            s.tau.is_some()
                && (is_lin(s.dstat) || s.gate.is_some_and(|(g, _)| is_lin(g)))
        });
        let need_mlp = active.iter().any(|&i| {
            let s = &seqs[i];
            s.tau.is_some()
                && (!is_lin(s.dstat) || s.gate.is_some_and(|(g, _)| !is_lin(g)))
        });
        let sc_lin = if need_lin { Some(fetch("score_lin")?) } else { None };
        let sc_mlp = if need_mlp { Some(fetch("score_mlp")?) } else { None };

        let mut k_row = vec![0.0f32; handle.row_elems()];
        let mut v_row = vec![0.0f32; handle.row_elems()];
        for &si in &active {
            let slot = slot_of[si];
            let seq = &mut *seqs[si];
            // fetch the one KV row this step wrote into the sequence's host
            // snapshot — the only per-step KV transfer
            let p = seq.pos;
            self.rt.kv_fetch_row(handle, slot, p, &mut k_row, &mut v_row)?;
            kv_down[si] += 4 * (k_row.len() + v_row.len()) as u64;
            for l in 0..layers {
                for h in 0..heads {
                    let dst = (l * heads + h) * (t_max * d_head) + p * d_head;
                    let src = (l * heads + h) * d_head;
                    seq.k[dst..dst + d_head].copy_from_slice(&k_row[src..src + d_head]);
                    seq.v[dst..dst + d_head].copy_from_slice(&v_row[src..src + d_head]);
                }
            }
            // the token we just fed occupies pos (the backend mirrors this
            // fill in the resident mask, so it is not a dirty change). An
            // engine-level pool can refuse the new block under pressure —
            // the sequence then finishes as CacheFull instead of admitting
            // unbudgeted bytes.
            if !seq.cache.fill((seq.pos + 1).min(t_max)) {
                seq.done = Some(DoneReason::CacheFull);
                events.push(StepEvent::Done { id: seq.id, reason: DoneReason::CacheFull });
                continue;
            }
            // credit the side rows the backend attended for this slot
            let qa = qstats.get(slot).copied().unwrap_or_default();
            if qa.rows > 0 {
                seq.cache.note_quant_attend(qa.rows);
                seq.decode_quant_attends += qa.rows;
            }
            let mut evicted = 0usize;
            let mut demoted = 0usize;
            let mut rehydrated = 0usize;
            if let Some(tau) = seq.tau {
                let pick = |st: Stat| {
                    if is_lin(st) {
                        sc_lin.as_ref()
                    } else {
                        sc_mlp.as_ref()
                    }
                };
                let sc = pick(seq.dstat)
                    .expect("decode scores fetched for threshold policies");
                // gated sequences buffer margins against threshold 0 (the
                // same transform prefill seeding applies — see above)
                let gate = seq
                    .gate
                    .map(|(gstat, gtau)| (pick(gstat).expect("gate scores fetched"), gtau));
                let eff_tau = if gate.is_some() { 0.0 } else { tau };
                // score tensors are [L, B, H]: collect this sequence's row
                let mut v = Vec::with_capacity(layers * heads);
                for l in 0..layers {
                    for h in 0..heads {
                        let s = sc.at(&[l, slot, h]);
                        v.push(match gate {
                            None => s,
                            Some((g, gtau)) => (s - tau).max(g.at(&[l, slot, h]) - gtau),
                        });
                    }
                }
                let tp = crate::util::now_micros();
                // rehydration scan: a demoted position returns to residency
                // when the step's incoming score for its head dips *below*
                // its stored score (rebound — it now outranks live traffic)
                // or when it would re-enter the protected window (backstop;
                // vacuous in normal flow since demotion never targets the
                // window). Device-local; the mask refresh rides the
                // existing dirty-flag path next step.
                if seq.tracked_demoted() > 0 {
                    let wstart = (seq.pos + 1).saturating_sub(self.window());
                    for l in 0..layers {
                        for h in 0..heads {
                            let lh = l * heads + h;
                            let incoming = v[lh];
                            let mut i = 0;
                            while i < seq.demoted_scores[lh].len() {
                                let (p, stored) = seq.demoted_scores[lh][i];
                                if (stored > incoming || p >= wstart)
                                    && seq.cache.rehydrate(l, h, p)
                                {
                                    self.rt.kv_rehydrate(handle, slot, l, h, p)?;
                                    seq.demoted_scores[lh].swap_remove(i);
                                    rehydrated += 1;
                                } else {
                                    i += 1;
                                }
                            }
                        }
                    }
                }
                let (ev, dem) = seq.sbuf.push_and_evict_tiered(
                    seq.pos,
                    v,
                    eff_tau,
                    seq.floor,
                    &mut seq.cache,
                );
                evicted = ev;
                demoted = dem.len();
                let tier = seq.cache.tier();
                for &(l, h, p, s) in &dem {
                    seq.demoted_scores[l * heads + h].push((p, s));
                    roundtrip_snapshot_row(
                        &mut seq.k, &mut seq.v, tier, heads, t_max, d_head, l, h, p,
                    );
                    self.rt.kv_demote(handle, slot, l, h, p, tier.bits, tier.group)?;
                }
                seq.decode_evictions += evicted;
                seq.decode_demotions += demoted;
                seq.decode_rehydrations += rehydrated;
                seq.policy_us += crate::util::now_micros() - tp;
            }
            let t = seq.sampler.sample(logits.row(&[slot]), &seq.sp);
            seq.pos += 1;
            if self.tok.is_stop(t, seq.sp.stop_at_newline) {
                seq.done = Some(DoneReason::Stop);
                events.push(StepEvent::Done { id: seq.id, reason: DoneReason::Stop });
            } else if seq.generated.len() + 1 >= seq.sp.max_new {
                // matches the pre-session decode loop: the final candidate
                // token is discarded once the budget is reached
                seq.done = Some(DoneReason::MaxTokens);
                events.push(StepEvent::Done { id: seq.id, reason: DoneReason::MaxTokens });
            } else {
                seq.generated.push(t);
                seq.cur = t;
                events.push(StepEvent::Token {
                    id: seq.id,
                    token: t,
                    text: self.tok.decode(&[t]),
                    evicted,
                    demoted,
                    rehydrated,
                    kv_up_bytes: kv_up[si],
                    kv_down_bytes: kv_down[si],
                });
            }
        }
        let dt = crate::util::now_micros() - t0;
        self.metrics.decode_step.lock().unwrap().record(dt);
        self.metrics
            .step_kv_up
            .lock()
            .unwrap()
            .record(kv_up.iter().sum::<u64>());
        self.metrics
            .step_kv_down
            .lock()
            .unwrap()
            .record(kv_down.iter().sum::<u64>());
        for &si in &active {
            seqs[si].decode_us += dt;
        }
        Ok(events)
    }

    /// Finalize a sequence into a [`GenResult`] (records request metrics).
    pub fn finish(&self, seq: &Sequence) -> GenResult {
        let st = seq.cache.stats();
        self.metrics.note_request(seq.generated.len(), st.compression());
        GenResult {
            text: self.tok.decode(&seq.generated),
            prompt_len: seq.toks.len(),
            tokens_out: seq.generated.len(),
            compression: st.compression(),
            prefill_us: seq.prefill_us,
            oracle_us: seq.oracle_us,
            decode_us: seq.decode_us,
            policy_us: seq.policy_us,
            decode_evictions: seq.decode_evictions,
            decode_demotions: seq.decode_demotions,
            decode_rehydrations: seq.decode_rehydrations,
            decode_quant_attends: seq.decode_quant_attends,
        }
    }

    /// Generate for a single prompt (B=1 decode path).
    pub fn generate(
        &self,
        prompt: &str,
        policy: &dyn PrunePolicy,
        sp: &SamplingParams,
    ) -> Result<GenResult> {
        let mut rs = self.generate_batch(&[prompt], policy, sp)?;
        Ok(rs.pop().unwrap())
    }

    /// Slot-batched generation: a thin loop over [`Engine::prefill`] +
    /// [`Engine::decode_step`]. All prompts share one policy and one set of
    /// sampling params (per-slot sampler seeds are derived as before); the
    /// continuous batcher uses the same primitives with per-request params.
    pub fn generate_batch(
        &self,
        prompts: &[&str],
        policy: &dyn PrunePolicy,
        sp: &SamplingParams,
    ) -> Result<Vec<GenResult>> {
        let nb = prompts.len();
        assert!(nb > 0);
        // fail early (before any prefill work) when the batch cannot decode
        self.rt
            .manifest
            .decode_bucket(nb)
            .ok_or_else(|| anyhow!("no decode bucket for {nb}"))?;
        let mut seqs: Vec<Sequence> = prompts
            .iter()
            .enumerate()
            .map(|(b, p)| {
                let mut sp_b = sp.clone();
                sp_b.seed = sp.seed.wrapping_add(b as u64 * 7919);
                self.sequence(b as u64, p, sp_b)
            })
            .collect();
        for seq in seqs.iter_mut() {
            self.prefill(seq, policy)?;
        }
        let mut group = self.decode_group();
        loop {
            let mut live: Vec<&mut Sequence> =
                seqs.iter_mut().filter(|s| !s.is_done()).collect();
            if live.is_empty() {
                break;
            }
            self.decode_step(&mut group, &mut live)?;
        }
        Ok(seqs.iter().map(|s| self.finish(s)).collect())
    }

    /// KVzip oracle double pass for one prompt: returns (s, s+) `[L,1,H,T]`.
    fn oracle_scores(&self, tokens: &[i32]) -> Result<(Tensor, Tensor)> {
        let man = &self.rt.manifest;
        let bucket = man
            .kvzip_bucket(tokens.len())
            .ok_or_else(|| anyhow!("no kvzip bucket for len {}", tokens.len()))?;
        let art = self.rt.artifact(&bucket)?;
        let t = art.meta.t;
        let mut padded = vec![self.tok.pad as i32; t];
        padded[..tokens.len()].copy_from_slice(tokens);
        let lens = [tokens.len() as i32];
        let outs = self.rt.exec(&art, &[Arg::I32(&padded, &[1, t]), Arg::I32(&lens, &[1])])?;
        let si = art.meta.output_index("s")?;
        let pi = art.meta.output_index("s_plus")?;
        Ok((
            self.rt.fetch_f32(&outs[si], &art.meta.outputs[si].shape)?,
            self.rt.fetch_f32(&outs[pi], &art.meta.outputs[pi].shape)?,
        ))
    }

    /// Teacher-forced answer scoring: mean NLL (nats/byte) of `answer`
    /// given `prompt` under the pruned cache. This is the smooth quality
    /// metric the benches report alongside exact-match accuracy — it
    /// degrades gracefully as pruning removes needed KV pairs, so the
    /// policy ranking is measurable at any model quality.
    ///
    /// Shorthand for [`Engine::score_answer_full`] returning only
    /// `(nll, compression)`.
    pub fn score_answer(
        &self,
        prompt: &str,
        answer: &str,
        policy: &dyn PrunePolicy,
    ) -> Result<(f64, f64)> {
        let a = self.score_answer_full(prompt, answer, policy)?;
        Ok((a.nll, a.compression))
    }

    /// Teacher-forced answer scoring with tier accounting (see
    /// [`Engine::score_answer`] for the metric itself).
    ///
    /// Two-threshold policies demote part of the prompt into the
    /// quantized side tier at prefill. This scorer prices the cache at
    /// that *steady state* (`kv_bytes`, `compression` — what the pairs
    /// cost while the request idles between prefill and answer), then
    /// teacher-forces the answer with the demoted band **scored from
    /// quantized form** ([`RescoreMode::QuantAttend`], the default): the
    /// band is parked on the backend via the fused demote-band op and the
    /// quantized decode path attends it in place, so no rehydration — and
    /// no resident re-charge — happens just to measure quality. The band
    /// still contributes with int8 round-trip error instead of being
    /// gone, which is the tier's faithfulness story on the
    /// accuracy-vs-bytes frontier; [`RescoreMode::Rehydrate`] keeps the
    /// legacy rehydrate-everything path for metamorphic comparison.
    pub fn score_answer_full(
        &self,
        prompt: &str,
        answer: &str,
        policy: &dyn PrunePolicy,
    ) -> Result<AnswerScore> {
        self.score_answer_mode(prompt, answer, policy, RescoreMode::QuantAttend)
    }

    /// [`Engine::score_answer_full`] with an explicit demoted-band
    /// treatment (see [`RescoreMode`]).
    pub fn score_answer_mode(
        &self,
        prompt: &str,
        answer: &str,
        policy: &dyn PrunePolicy,
        mode: RescoreMode,
    ) -> Result<AnswerScore> {
        let man = &self.rt.manifest;
        let (layers, heads, t_max) =
            (man.model.n_layers, man.model.n_kv_heads, man.model.t_max);
        let toks = self.tok.encode(prompt, self.max_prompt());
        let n = toks.len();
        let ans: Vec<i32> = answer.bytes().map(|b| b as i32).collect();
        let bucket = man
            .prefill_bucket(n, 1)
            .ok_or_else(|| anyhow!("no prefill bucket for {n}"))?;
        let pf = self.rt.artifact(&bucket)?;
        let pt = pf.meta.t;
        let mut tok_flat = vec![self.tok.pad as i32; pt];
        tok_flat[..n].copy_from_slice(&toks);
        let lens = [n as i32];
        let outs =
            self.rt.exec(&pf, &[Arg::I32(&tok_flat, &[1, pt]), Arg::I32(&lens, &[1])])?;
        let fetch = |name: &str| -> Result<Tensor> {
            let i = pf.meta.output_index(name)?;
            self.rt.fetch_f32(&outs[i], &pf.meta.outputs[i].shape)
        };
        let logits0 = fetch("logits")?;
        let stats = PrefillStats {
            score_lin: fetch("score_lin")?,
            score_mlp: fetch("score_mlp")?,
            max_attn: fetch("max_attn")?,
            plus_attn: fetch("plus_attn")?,
            cum_attn: fetch("cum_attn")?,
            win_attn: fetch("win_attn")?,
            vnorm: fetch("vnorm")?,
            knorm: fetch("knorm")?,
        };
        let oracle = if policy.needs_oracle() {
            Some(self.oracle_scores(&toks)?)
        } else {
            None
        };
        let mut cache = PagedKvCache::new_tiered(
            layers,
            heads,
            t_max,
            self.tier_config_bits(policy.tier_bits()),
        );
        cache.fill(n);
        policy.prefill_prune(&stats.view(0, oracle.as_ref()), n, &mut cache);
        // price the cache at its post-prune steady state, *before*
        // answer-time rehydration brings the demoted band back
        let steady = cache.stats();
        let compression = steady.compression();

        // Collect the demoted band and round-trip its host rows through
        // the tier's quantizer either way (the side tier stores int8; the
        // answer must attend to what it stored, not the original f32 —
        // and quantization is stable under re-encoding, so the backend's
        // demote-band re-encode reproduces the same payload bitwise).
        let mut kc = fetch("kcache")?;
        let mut vc = fetch("vcache")?;
        let mut band = vec![];
        let mut rehydrated = 0usize;
        if steady.demoted > 0 {
            let tier = cache.tier();
            let d = man.model.d_head;
            for l in 0..layers {
                for h in 0..heads {
                    for p in cache.demoted_positions(l, h) {
                        roundtrip_snapshot_row(
                            &mut kc.data, &mut vc.data, tier, heads, t_max, d, l, h, p,
                        );
                        band.push((l, h, p));
                    }
                }
            }
            // legacy mode only: bring the band back to residency before
            // scoring (re-charges resident blocks)
            if matches!(mode, RescoreMode::Rehydrate) {
                for &(l, h, p) in &band {
                    if cache.rehydrate(l, h, p) {
                        rehydrated += 1;
                    }
                }
            }
        }

        // resident B=1 teacher-forcing session: scatter the prefill cache
        // once; each step appends its row in place on the backend (the fed
        // answer tokens become attendable without any mask re-upload)
        let dec = self.rt.artifact(&man.decode_bucket(1).unwrap())?;
        let mut group = self.decode_group();
        group.handle = Some(self.rt.kv_alloc(dec.meta.batch)?);
        group.slots = vec![0; dec.meta.batch];
        let handle = group.handle.as_ref().unwrap();
        self.rt.kv_scatter(handle, 0, &kc.data, &vc.data)?;
        self.rt.kv_write_mask(handle, 0, &cache.mask_f32())?;
        // quant-attend mode: park the demoted band on the backend (fused
        // band demote) so the quantized decode path scores it in place —
        // the band stays masked off and demoted, no resident re-charge
        let quant = matches!(mode, RescoreMode::QuantAttend) && !band.is_empty();
        if quant {
            let tier = cache.tier();
            self.rt.kv_demote_band(handle, 0, &band, tier.bits, tier.group)?;
        }

        // NLL of answer byte i under logits from step i-1 (teacher forcing).
        let mut nll = 0.0f64;
        let mut count = 0usize;
        let mut logits = logits0;
        let mut quant_attended = 0usize;
        for (i, &a) in ans.iter().enumerate() {
            nll += nll_of(logits.row(&[0]), a);
            count += 1;
            let pos = n + i;
            if pos >= t_max || i == ans.len() - 1 {
                break;
            }
            let outs = if quant {
                let (outs, qstats) =
                    self.rt.exec_decode_resident_quant(&dec, &[a], &[pos as i32], handle)?;
                let rows: usize = qstats.iter().map(|s| s.rows).sum();
                if rows > 0 {
                    let bytes: u64 = qstats.iter().map(|s| s.bytes as u64).sum();
                    quant_attended += rows;
                    cache.note_quant_attend(rows);
                    self.metrics.note_quant_attend(rows as u64, bytes);
                }
                outs
            } else {
                self.rt.exec_decode_resident(&dec, &[a], &[pos as i32], handle)?
            };
            let li = dec.meta.output_index("logits")?;
            let ri = dec.meta.resident_output_index("logits")?;
            logits = self.rt.fetch_f32(&outs[ri], &dec.meta.outputs[li].shape)?;
        }
        Ok(AnswerScore {
            nll: nll / count.max(1) as f64,
            compression,
            kv_bytes: steady.kv_bytes(),
            demoted: steady.demoted,
            rehydrated,
            quant_attended,
        })
    }
}

/// How [`Engine::score_answer_mode`] treats a prefill's demoted band
/// while teacher-forcing the answer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RescoreMode {
    /// Score the band from quantized form in place: the fused demote-band
    /// op parks it on the backend and the quantized decode path attends
    /// it with zero rehydrations and zero resident re-charge. The
    /// default ([`Engine::score_answer_full`]).
    QuantAttend,
    /// Legacy: round-trip + rehydrate every demoted row back to
    /// residency, then score over the fully-resident cache. Kept for
    /// metamorphic comparison — both modes must produce bitwise-identical
    /// NLL and eviction decisions.
    Rehydrate,
}

/// Result of [`Engine::score_answer_full`]: the teacher-forced quality
/// metric plus the steady-state tier accounting behind the leaderboard's
/// accuracy-vs-bytes frontier.
#[derive(Debug, Clone, Copy)]
pub struct AnswerScore {
    /// Mean NLL of the answer in nats/byte (lower is better).
    pub nll: f64,
    /// Removed fraction at the post-prune steady state (demoted pairs
    /// count as removed — they left the resident f32 tier).
    pub compression: f64,
    /// Total cache bytes at the steady state: block-granular resident f32
    /// plus per-entry quantized side-tier bytes.
    pub kv_bytes: usize,
    /// Prompt positions the policy demoted into the side tier.
    pub demoted: usize,
    /// Demoted positions rehydrated before the answer was scored (0 in
    /// the default [`RescoreMode::QuantAttend`] mode).
    pub rehydrated: usize,
    /// Demoted rows attended in quantized form while scoring, summed over
    /// teacher-forcing steps (0 in [`RescoreMode::Rehydrate`] mode).
    pub quant_attended: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;
    use crate::workload;
    use std::sync::Arc;

    /// Both rehydration triggers, forced deterministically by seeding the
    /// ledger by hand (white-box: the natural decode flow only reaches
    /// them on data-dependent score rebounds). One demoted entry deep in
    /// the prompt carries a `+inf` stored score — any real incoming score
    /// sits below it, so the *rebound* rule must rehydrate it. A second
    /// entry at the window edge carries `-inf` — rebound can never fire,
    /// so only the *window re-entry backstop* can bring it home. One
    /// decode step must recover both, drain the ledger, and restore the
    /// cache's kept bits.
    #[test]
    fn forced_rehydration_rebound_and_window_backstop() {
        let e = Engine::new(Arc::new(Runtime::reference()));
        let mut rng = Rng::new(5);
        let task = workload::ruler_instance("niah_single_1", 180, &mut rng);
        // tau = -1000 keeps everything, so no natural demotion competes
        // with the two hand-planted entries; the floor arms the tier path.
        let policy = policies::by_name("kvzap_mlp:-1000:floor=-1000", e.window()).unwrap();
        let mut sp = SamplingParams::greedy(4);
        sp.stop_at_newline = false;
        let mut s = e.sequence(1, &task.prompt, sp);
        e.prefill(&mut s, policy.as_ref()).unwrap();
        assert_eq!(s.cache.stats().demoted, 0, "tau=-1000 must not demote naturally");
        assert!(s.floor.is_some(), "the floor must arm the tiered decode path");

        let heads = e.rt.manifest.model.n_kv_heads;
        let edge = s.pos - 1; // inside the protected window
        assert!(s.cache.demote(0, 0, 0), "manual demotion deep in the prompt");
        s.demoted_scores[0].push((0, f32::MAX));
        assert!(s.cache.demote(0, heads - 1, edge), "manual demotion at the window edge");
        s.demoted_scores[heads - 1].push((edge, f32::MIN));
        assert_eq!(s.tracked_demoted(), 2);
        assert_eq!(s.cache.stats().demoted, 2);

        let mut group = e.decode_group();
        let mut set = vec![&mut s];
        e.decode_step(&mut group, &mut set).unwrap();
        assert_eq!(
            s.decode_rehydrations, 2,
            "rebound and window backstop must both fire on the first step"
        );
        assert_eq!(s.tracked_demoted(), 0, "the ledger drains");
        assert_eq!(s.cache.stats().demoted, 0, "the side tier empties");
        assert_eq!(s.cache.stats().side_bytes, 0);
        assert!(s.cache.is_kept(0, 0, 0), "rebound entry is resident again");
        assert!(s.cache.is_kept(0, heads - 1, edge), "backstop entry is resident again");
        assert_eq!(s.decode_demotions, 0, "tau=-1000 demotes nothing on its own");
    }

    /// A prefilled sequence with a hand-planted demoted band deep in the
    /// prompt: stored scores of `f32::MIN` mean the rebound rule can never
    /// fire, and positions 1..=3 stay far below the window start for the
    /// whole budget, so the backstop never fires either — the band stays
    /// demoted for every decode step (attended only via the quantized
    /// side path). Snapshot rows are round-tripped exactly as the natural
    /// demotion flow does, so group-join scatters stay consistent.
    fn demoted_band_seq(e: &Engine, seed: u64, max_new: usize) -> Sequence {
        let mut rng = Rng::new(seed);
        let task = workload::ruler_instance("niah_single_1", 180, &mut rng);
        let policy = policies::by_name("kvzap_mlp:-1000:floor=-1000", e.window()).unwrap();
        let mut sp = SamplingParams::greedy(max_new);
        sp.stop_at_newline = false;
        let mut s = e.sequence(7, &task.prompt, sp);
        e.prefill(&mut s, policy.as_ref()).unwrap();
        assert_eq!(s.cache.stats().demoted, 0, "tau=-1000 must not demote naturally");
        let man = &e.rt.manifest;
        let (heads, t_max, d) =
            (man.model.n_kv_heads, man.model.t_max, man.model.d_head);
        let tier = s.cache.tier();
        for l in 0..man.model.n_layers {
            for h in 0..heads {
                for p in 1..4 {
                    assert!(s.cache.demote(l, h, p));
                    s.demoted_scores[l * heads + h].push((p, f32::MIN));
                    roundtrip_snapshot_row(&mut s.k, &mut s.v, tier, heads, t_max, d, l, h, p);
                }
            }
        }
        s
    }

    /// The quantized decode path's steady-state contract: a sequence with
    /// a demoted band performs ZERO rehydrations while decoding — the band
    /// is attended in place, in quantized form, every step — and the
    /// white-box counters (sequence, cache telemetry, engine metrics,
    /// runtime transfer) all agree on exactly how many rows that was.
    #[test]
    fn steady_state_decode_attends_quantized_rows_without_rehydration() {
        let e = Engine::new(Arc::new(Runtime::reference()));
        let mut s = demoted_band_seq(&e, 11, 6);
        let band = s.tracked_demoted();
        assert!(band > 0);
        let bpe = s.cache.tier().bytes_per_entry();

        let mut group = e.decode_group();
        let mut steps = 0usize;
        while !s.is_done() {
            let mut set = vec![&mut s];
            e.decode_step(&mut group, &mut set).unwrap();
            steps += 1;
        }
        assert!(steps >= 1, "at least one decode step must have executed");
        assert_eq!(s.decode_rehydrations, 0, "steady state performs no kv_rehydrate");
        assert_eq!(s.cache.stats().demoted, band, "the band stays demoted");
        assert_eq!(
            s.decode_quant_attends,
            steps * band,
            "every step attends the whole band in place"
        );
        assert_eq!(s.cache.quant_attended_rows(), steps * band);
        assert_eq!(s.cache.stats().quant_attended_bytes, steps * band * bpe);
        let snap = e.rt.transfer.snapshot();
        assert_eq!(snap.quant_attend_rows, (steps * band) as u64);
        assert_eq!(snap.quant_attend_bytes, (steps * band * bpe) as u64);
    }

    /// Output agreement with the old rehydrate-everything contract: twin
    /// sequences share a seed and the same hand-planted band; twin B
    /// rehydrates every demoted row (its lossy round-tripped payload is
    /// already in the snapshot) before decoding, twin A attends the band
    /// in quantized form. Both must generate the same text — the side
    /// entries dequantize to exactly the values twin B holds resident, so
    /// only float summation order differs.
    #[test]
    fn quant_attend_generation_matches_rehydrate_everything() {
        let e = Engine::new(Arc::new(Runtime::reference()));
        let mut a = demoted_band_seq(&e, 13, 8);
        let mut b = demoted_band_seq(&e, 13, 8);
        let heads = e.rt.manifest.model.n_kv_heads;
        for l in 0..e.rt.manifest.model.n_layers {
            for h in 0..heads {
                for (p, _) in std::mem::take(&mut b.demoted_scores[l * heads + h]) {
                    assert!(b.cache.rehydrate(l, h, p));
                }
            }
        }
        assert_eq!(b.cache.stats().demoted, 0, "twin B starts fully rehydrated");

        let mut ga = e.decode_group();
        while !a.is_done() {
            let mut set = vec![&mut a];
            e.decode_step(&mut ga, &mut set).unwrap();
        }
        let mut gb = e.decode_group();
        while !b.is_done() {
            let mut set = vec![&mut b];
            e.decode_step(&mut gb, &mut set).unwrap();
        }
        assert!(a.decode_quant_attends > 0, "twin A served the band in place");
        assert_eq!(a.decode_rehydrations, 0);
        assert_eq!(b.decode_quant_attends, 0, "twin B has no side entries left");
        assert_eq!(
            e.finish(&a).text,
            e.finish(&b).text,
            "quant-attend decode must match the rehydrate-everything path"
        );
    }
}
