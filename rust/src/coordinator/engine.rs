//! The generation engine: prefill → prune → masked decode, exposed as
//! step-level sequence sessions. This is the request hot path — python
//! never runs here.
//!
//! The public surface is built from three primitives:
//!
//! * [`Sequence`] — one in-flight generation: prompt tokens, position,
//!   [`PagedKvCache`], [`ScoreBuffer`], sampler, per-sequence
//!   [`SamplingParams`] and pruning configuration, plus its host-side KV
//!   copy so it can join/leave decode groups between steps.
//! * [`Engine::prefill`] — run the prefill bucket for one sequence, apply
//!   the policy's prefill pruning, sample the first token.
//! * [`Engine::decode_step`] — advance any set of live sequences by one
//!   token together (they share one decode-bucket execution), emitting
//!   [`StepEvent`]s (token, eviction count, done reason).
//!
//! [`Engine::generate`] / [`Engine::generate_batch`] are thin loops over
//! these primitives; the continuous batcher drives the same primitives but
//! admits and removes sequences between steps (see batcher.rs).
//!
//! The engine is backend-generic: it only sees the [`Runtime`] facade and
//! opaque [`Buffer`]s, so the same code path drives the hermetic reference
//! backend and the PJRT artifacts. Data movement per decode step (see
//! DESIGN.md §Perf): each sequence keeps a host copy of its KV rows; the
//! step packs the group's rows + keep-masks, executes the decode bucket,
//! and copies back only the one new KV row per sequence. (Keeping the
//! group cache device-resident across steps when membership is unchanged
//! is an open perf item — see ROADMAP.)

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::sampler::{Sampler, SamplingParams};
use crate::kvcache::PagedKvCache;
use crate::metrics::EngineMetrics;
use crate::policies::{PrefillView, PrunePolicy, ScoreBuffer, Stat};
use crate::runtime::{Arg, Runtime, Tensor};
use crate::workload::ByteTokenizer;

pub struct Engine {
    pub rt: Arc<Runtime>,
    pub tok: ByteTokenizer,
    pub metrics: EngineMetrics,
}

/// -log softmax(logits)[target] in nats.
fn nll_of(logits: &[f32], target: i32) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&x| ((x as f64) - m).exp()).sum::<f64>().ln() + m;
    lse - logits[target as usize] as f64
}

#[derive(Debug, Clone)]
pub struct GenResult {
    pub text: String,
    pub prompt_len: usize,
    pub tokens_out: usize,
    /// Removed fraction of the KV cache at end of generation (the paper's
    /// "compression ratio (removed fraction)", Table 2).
    pub compression: f64,
    pub prefill_us: u64,
    pub oracle_us: u64,
    pub decode_us: u64,
    pub policy_us: u64,
    pub decode_evictions: usize,
}

/// Why a sequence stopped generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DoneReason {
    /// The model emitted a stop token (EOS/PAD, or newline for
    /// newline-terminated task grammars).
    Stop,
    /// The per-sequence `max_new` token budget was reached.
    MaxTokens,
    /// The KV cache ran out of positions (`t_max`).
    CacheFull,
    /// The request was cancelled mid-generation.
    Cancelled,
}

impl DoneReason {
    pub fn as_str(self) -> &'static str {
        match self {
            DoneReason::Stop => "stop",
            DoneReason::MaxTokens => "max_tokens",
            DoneReason::CacheFull => "cache_full",
            DoneReason::Cancelled => "cancelled",
        }
    }
}

/// What one engine step produced for one sequence.
#[derive(Debug, Clone)]
pub enum StepEvent {
    /// A new token was accepted into the sequence. `text` is its decoded
    /// byte (the tokenizer is byte-level); `evicted` counts KV pairs the
    /// threshold policy removed at this step (Algorithm 1's delayed
    /// eviction).
    Token { id: u64, token: i32, text: String, evicted: usize },
    /// The sequence finished; no more events will follow for `id`.
    Done { id: u64, reason: DoneReason },
}

/// One in-flight generation: everything the engine needs to advance a
/// request one token at a time. Create with [`Engine::sequence`], run
/// [`Engine::prefill`] once, then pass to [`Engine::decode_step`] together
/// with any other live sequences until [`Sequence::is_done`].
pub struct Sequence {
    pub id: u64,
    pub sp: SamplingParams,
    /// Human-readable policy label (set at prefill; for logs/metrics).
    pub policy_name: String,
    /// Prompt token ids (BOS + bytes, truncated to the max prefill bucket).
    toks: Vec<i32>,
    /// Accepted generated tokens.
    pub generated: Vec<i32>,
    /// Next cache position to be written by decode (== tokens fed so far).
    pos: usize,
    /// Token to feed at the next decode step.
    cur: i32,
    cache: PagedKvCache,
    sbuf: ScoreBuffer,
    /// Decode-time eviction threshold (None: no decode pruning).
    tau: Option<f32>,
    /// Which surrogate drives decode-time scores.
    dstat: Stat,
    sampler: Sampler,
    /// Host copy of this sequence's KV rows, `[L, H, t_max, D]` — lets the
    /// sequence join a decode group in any slot at any step.
    k: Vec<f32>,
    v: Vec<f32>,
    done: Option<DoneReason>,
    prefilled: bool,
    pub decode_evictions: usize,
    pub prefill_us: u64,
    pub oracle_us: u64,
    pub decode_us: u64,
    pub policy_us: u64,
}

impl Sequence {
    pub fn is_done(&self) -> bool {
        self.done.is_some()
    }

    pub fn done_reason(&self) -> Option<DoneReason> {
        self.done
    }

    pub fn prompt_len(&self) -> usize {
        self.toks.len()
    }

    pub fn tokens_out(&self) -> usize {
        self.generated.len()
    }

    /// Removed fraction of this sequence's KV cache so far.
    pub fn compression(&self) -> f64 {
        self.cache.stats().compression()
    }

    /// Mark the sequence as cancelled; it will be skipped by subsequent
    /// decode steps. No-op when the sequence already finished.
    pub fn cancel(&mut self) {
        if self.done.is_none() {
            self.done = Some(DoneReason::Cancelled);
        }
    }
}

struct PrefillStats {
    score_lin: Tensor,
    score_mlp: Tensor,
    max_attn: Tensor,
    plus_attn: Tensor,
    cum_attn: Tensor,
    win_attn: Tensor,
    vnorm: Tensor,
    knorm: Tensor,
}

impl PrefillStats {
    fn view<'a>(
        &'a self,
        b: usize,
        oracle: Option<&'a (Tensor, Tensor)>,
    ) -> PrefillView<'a> {
        PrefillView {
            b,
            score_lin: &self.score_lin,
            score_mlp: &self.score_mlp,
            max_attn: &self.max_attn,
            plus_attn: &self.plus_attn,
            cum_attn: &self.cum_attn,
            win_attn: &self.win_attn,
            vnorm: &self.vnorm,
            knorm: &self.knorm,
            oracle_s: oracle.map(|o| &o.0),
            oracle_s_plus: oracle.map(|o| &o.1),
        }
    }
}

impl Engine {
    pub fn new(rt: Arc<Runtime>) -> Engine {
        Engine { rt, tok: ByteTokenizer::default(), metrics: EngineMetrics::default() }
    }

    pub fn window(&self) -> usize {
        self.rt.manifest.window
    }

    /// Largest prompt (in tokens incl. BOS) the artifacts can prefill.
    pub fn max_prompt(&self) -> usize {
        *self.rt.manifest.buckets.prefill_t.iter().max().unwrap()
    }

    /// Create a fresh (not yet prefilled) sequence for `prompt`.
    pub fn sequence(&self, id: u64, prompt: &str, sp: SamplingParams) -> Sequence {
        let man = &self.rt.manifest;
        let (layers, heads, t_max) =
            (man.model.n_layers, man.model.n_kv_heads, man.model.t_max);
        let seed = sp.seed;
        Sequence {
            id,
            toks: self.tok.encode(prompt, self.max_prompt()),
            generated: vec![],
            pos: 0,
            cur: self.tok.pad as i32,
            cache: PagedKvCache::new(layers, heads, t_max),
            sbuf: ScoreBuffer::new(self.window(), layers, heads),
            tau: None,
            dstat: Stat::ScoreMlp,
            sampler: Sampler::new(seed),
            sp,
            policy_name: String::new(),
            k: vec![],
            v: vec![],
            done: None,
            prefilled: false,
            decode_evictions: 0,
            prefill_us: 0,
            oracle_us: 0,
            decode_us: 0,
            policy_us: 0,
        }
    }

    /// Prefill one sequence: run the prefill bucket, apply `policy`'s
    /// prefill-time pruning, seed the decode score window, and sample the
    /// first token from the prefill logits. Returns the emitted events
    /// (first token, and possibly an immediate done).
    pub fn prefill(&self, seq: &mut Sequence, policy: &dyn PrunePolicy) -> Result<Vec<StepEvent>> {
        assert!(!seq.prefilled, "sequence {} already prefilled", seq.id);
        let man = &self.rt.manifest;
        let n = seq.toks.len();
        let bucket = man
            .prefill_bucket(n, 1)
            .ok_or_else(|| anyhow!("no prefill bucket for len {n}"))?;
        let pf = self.rt.artifact(&bucket)?;
        let pt = pf.meta.t;
        let mut tok_flat = vec![self.tok.pad as i32; pt];
        tok_flat[..n].copy_from_slice(&seq.toks);
        let lens = [n as i32];

        let t0 = crate::util::now_micros();
        let outs =
            self.rt.exec(&pf, &[Arg::I32(&tok_flat, &[1, pt]), Arg::I32(&lens, &[1])])?;
        seq.prefill_us = crate::util::now_micros() - t0;
        self.metrics.prefill.lock().unwrap().record(seq.prefill_us);

        let fetch = |name: &str| -> Result<Tensor> {
            let i = pf.meta.output_index(name)?;
            self.rt.fetch_f32(&outs[i], &pf.meta.outputs[i].shape)
        };
        let logits0 = fetch("logits")?;
        let stats = PrefillStats {
            score_lin: fetch("score_lin")?,
            score_mlp: fetch("score_mlp")?,
            max_attn: fetch("max_attn")?,
            plus_attn: fetch("plus_attn")?,
            cum_attn: fetch("cum_attn")?,
            win_attn: fetch("win_attn")?,
            vnorm: fetch("vnorm")?,
            knorm: fetch("knorm")?,
        };
        seq.k = fetch("kcache")?.data;
        seq.v = fetch("vcache")?.data;

        // oracle double pass (KVzip / KVzip+ baselines only)
        let oracle = if policy.needs_oracle() {
            let t0 = crate::util::now_micros();
            let o = self.oracle_scores(&seq.toks)?;
            seq.oracle_us = crate::util::now_micros() - t0;
            self.metrics.oracle.lock().unwrap().record(seq.oracle_us);
            Some(o)
        } else {
            None
        };

        // prune after prefill + seed the decode score window
        let t0 = crate::util::now_micros();
        seq.cache.fill(n);
        policy.prefill_prune(&stats.view(0, oracle.as_ref()), n, &mut seq.cache);
        seq.tau = policy.decode_threshold();
        seq.dstat = policy.decode_stat();
        if seq.tau.is_some() {
            let view = stats.view(0, None);
            let dstat = seq.dstat;
            seq.sbuf.seed_from_prefill(n, |l, h, pos| view.row(dstat, l, h)[pos]);
        }
        seq.policy_us = crate::util::now_micros() - t0;
        seq.policy_name = policy.name();
        seq.prefilled = true;
        seq.pos = n;

        // first token comes from the prefill logits
        let mut events = vec![];
        let t = seq.sampler.sample(logits0.row(&[0]), &seq.sp);
        if self.tok.is_stop(t, seq.sp.stop_at_newline) {
            seq.done = Some(DoneReason::Stop);
            events.push(StepEvent::Done { id: seq.id, reason: DoneReason::Stop });
        } else {
            seq.generated.push(t);
            seq.cur = t;
            events.push(StepEvent::Token {
                id: seq.id,
                token: t,
                text: self.tok.decode(&[t]),
                evicted: 0,
            });
            if seq.generated.len() >= seq.sp.max_new {
                seq.done = Some(DoneReason::MaxTokens);
                events.push(StepEvent::Done { id: seq.id, reason: DoneReason::MaxTokens });
            }
        }
        Ok(events)
    }

    /// Advance every live sequence in `seqs` by one decode step. The
    /// sequences share one decode-bucket execution (slot-batched); done or
    /// not-yet-prefilled sequences are skipped, so a scheduler can pass a
    /// stable set while membership changes between steps. Returns the
    /// step's events in sequence order.
    pub fn decode_step(&self, seqs: &mut [&mut Sequence]) -> Result<Vec<StepEvent>> {
        let man = &self.rt.manifest;
        let (layers, heads, t_max, d_head) = (
            man.model.n_layers,
            man.model.n_kv_heads,
            man.model.t_max,
            man.model.d_head,
        );
        let mut events = vec![];
        // sequences that would overflow the cache stop here
        for seq in seqs.iter_mut() {
            if seq.prefilled && seq.done.is_none() && seq.pos >= t_max {
                seq.done = Some(DoneReason::CacheFull);
                events.push(StepEvent::Done { id: seq.id, reason: DoneReason::CacheFull });
            }
        }
        let active: Vec<usize> = (0..seqs.len())
            .filter(|&i| seqs[i].prefilled && seqs[i].done.is_none())
            .collect();
        if active.is_empty() {
            return Ok(events);
        }
        let nb = active.len();
        let bucket =
            man.decode_bucket(nb).ok_or_else(|| anyhow!("no decode bucket for {nb}"))?;
        let dec = self.rt.artifact(&bucket)?;
        let db = dec.meta.batch;

        let t0 = crate::util::now_micros();
        // pack the group: per-sequence host KV rows + keep-masks
        let head_len = t_max * d_head;
        let mut kc = vec![0.0f32; layers * db * heads * head_len];
        let mut vc = vec![0.0f32; layers * db * heads * head_len];
        let mut mask = vec![0.0f32; layers * db * heads * t_max];
        let mut cur = vec![self.tok.pad as i32; db];
        let mut pos_i32 = vec![(t_max - 1) as i32; db];
        for (slot, &si) in active.iter().enumerate() {
            let seq = &*seqs[si];
            let m = seq.cache.mask_f32(); // [L, H, t_max]
            for l in 0..layers {
                for h in 0..heads {
                    let s_off = (l * heads + h) * head_len;
                    let g_off = ((l * db + slot) * heads + h) * head_len;
                    kc[g_off..g_off + head_len]
                        .copy_from_slice(&seq.k[s_off..s_off + head_len]);
                    vc[g_off..g_off + head_len]
                        .copy_from_slice(&seq.v[s_off..s_off + head_len]);
                    let sm = (l * heads + h) * t_max;
                    let gm = ((l * db + slot) * heads + h) * t_max;
                    mask[gm..gm + t_max].copy_from_slice(&m[sm..sm + t_max]);
                }
            }
            cur[slot] = seq.cur;
            pos_i32[slot] = seq.pos as i32;
        }
        let cache_dims = [layers, db, heads, t_max, d_head];
        let kc_buf = self.rt.upload_f32(&kc, &cache_dims)?;
        let vc_buf = self.rt.upload_f32(&vc, &cache_dims)?;
        let mask_buf = self.rt.upload_f32(&mask, &[layers, db, heads, t_max])?;
        let outs = self.rt.exec(
            &dec,
            &[
                Arg::I32(&cur, &[db]),
                Arg::I32(&pos_i32, &[db]),
                Arg::Buf(&kc_buf),
                Arg::Buf(&vc_buf),
                Arg::Buf(&mask_buf),
            ],
        )?;
        let fetch = |name: &str| -> Result<Tensor> {
            let i = dec.meta.output_index(name)?;
            self.rt.fetch_f32(&outs[i], &dec.meta.outputs[i].shape)
        };
        let logits = fetch("logits")?;
        let need_lin = active
            .iter()
            .any(|&i| seqs[i].tau.is_some() && seqs[i].dstat == Stat::ScoreLin);
        let need_mlp = active
            .iter()
            .any(|&i| seqs[i].tau.is_some() && seqs[i].dstat != Stat::ScoreLin);
        let sc_lin = if need_lin { Some(fetch("score_lin")?) } else { None };
        let sc_mlp = if need_mlp { Some(fetch("score_mlp")?) } else { None };
        let kc_out = fetch("kcache")?;
        let vc_out = fetch("vcache")?;

        for (slot, &si) in active.iter().enumerate() {
            let seq = &mut *seqs[si];
            // copy back the one KV row this step wrote for this sequence
            let p = seq.pos;
            for l in 0..layers {
                for h in 0..heads {
                    let s_off = (l * heads + h) * head_len + p * d_head;
                    let g_off = ((l * db + slot) * heads + h) * head_len + p * d_head;
                    seq.k[s_off..s_off + d_head]
                        .copy_from_slice(&kc_out.data[g_off..g_off + d_head]);
                    seq.v[s_off..s_off + d_head]
                        .copy_from_slice(&vc_out.data[g_off..g_off + d_head]);
                }
            }
            // the token we just fed occupies pos
            seq.cache.fill((seq.pos + 1).min(t_max));
            let mut evicted = 0usize;
            if let Some(tau) = seq.tau {
                let sc = if seq.dstat == Stat::ScoreLin {
                    sc_lin.as_ref()
                } else {
                    sc_mlp.as_ref()
                };
                let sc = sc.expect("decode scores fetched for threshold policies");
                // sc is [L, B, H]: collect this sequence's row
                let mut v = Vec::with_capacity(layers * heads);
                for l in 0..layers {
                    for h in 0..heads {
                        v.push(sc.at(&[l, slot, h]));
                    }
                }
                let tp = crate::util::now_micros();
                evicted = seq.sbuf.push_and_evict(seq.pos, v, tau, &mut seq.cache);
                seq.decode_evictions += evicted;
                seq.policy_us += crate::util::now_micros() - tp;
            }
            let t = seq.sampler.sample(logits.row(&[slot]), &seq.sp);
            seq.pos += 1;
            if self.tok.is_stop(t, seq.sp.stop_at_newline) {
                seq.done = Some(DoneReason::Stop);
                events.push(StepEvent::Done { id: seq.id, reason: DoneReason::Stop });
            } else if seq.generated.len() + 1 >= seq.sp.max_new {
                // matches the pre-session decode loop: the final candidate
                // token is discarded once the budget is reached
                seq.done = Some(DoneReason::MaxTokens);
                events.push(StepEvent::Done { id: seq.id, reason: DoneReason::MaxTokens });
            } else {
                seq.generated.push(t);
                seq.cur = t;
                events.push(StepEvent::Token {
                    id: seq.id,
                    token: t,
                    text: self.tok.decode(&[t]),
                    evicted,
                });
            }
        }
        let dt = crate::util::now_micros() - t0;
        self.metrics.decode_step.lock().unwrap().record(dt);
        for &si in &active {
            seqs[si].decode_us += dt;
        }
        Ok(events)
    }

    /// Finalize a sequence into a [`GenResult`] (records request metrics).
    pub fn finish(&self, seq: &Sequence) -> GenResult {
        let st = seq.cache.stats();
        self.metrics.note_request(seq.generated.len(), st.compression());
        GenResult {
            text: self.tok.decode(&seq.generated),
            prompt_len: seq.toks.len(),
            tokens_out: seq.generated.len(),
            compression: st.compression(),
            prefill_us: seq.prefill_us,
            oracle_us: seq.oracle_us,
            decode_us: seq.decode_us,
            policy_us: seq.policy_us,
            decode_evictions: seq.decode_evictions,
        }
    }

    /// Generate for a single prompt (B=1 decode path).
    pub fn generate(
        &self,
        prompt: &str,
        policy: &dyn PrunePolicy,
        sp: &SamplingParams,
    ) -> Result<GenResult> {
        let mut rs = self.generate_batch(&[prompt], policy, sp)?;
        Ok(rs.pop().unwrap())
    }

    /// Slot-batched generation: a thin loop over [`Engine::prefill`] +
    /// [`Engine::decode_step`]. All prompts share one policy and one set of
    /// sampling params (per-slot sampler seeds are derived as before); the
    /// continuous batcher uses the same primitives with per-request params.
    pub fn generate_batch(
        &self,
        prompts: &[&str],
        policy: &dyn PrunePolicy,
        sp: &SamplingParams,
    ) -> Result<Vec<GenResult>> {
        let nb = prompts.len();
        assert!(nb > 0);
        // fail early (before any prefill work) when the batch cannot decode
        self.rt
            .manifest
            .decode_bucket(nb)
            .ok_or_else(|| anyhow!("no decode bucket for {nb}"))?;
        let mut seqs: Vec<Sequence> = prompts
            .iter()
            .enumerate()
            .map(|(b, p)| {
                let mut sp_b = sp.clone();
                sp_b.seed = sp.seed.wrapping_add(b as u64 * 7919);
                self.sequence(b as u64, p, sp_b)
            })
            .collect();
        for seq in seqs.iter_mut() {
            self.prefill(seq, policy)?;
        }
        loop {
            let mut live: Vec<&mut Sequence> =
                seqs.iter_mut().filter(|s| !s.is_done()).collect();
            if live.is_empty() {
                break;
            }
            self.decode_step(&mut live)?;
        }
        Ok(seqs.iter().map(|s| self.finish(s)).collect())
    }

    /// KVzip oracle double pass for one prompt: returns (s, s+) `[L,1,H,T]`.
    fn oracle_scores(&self, tokens: &[i32]) -> Result<(Tensor, Tensor)> {
        let man = &self.rt.manifest;
        let bucket = man
            .kvzip_bucket(tokens.len())
            .ok_or_else(|| anyhow!("no kvzip bucket for len {}", tokens.len()))?;
        let art = self.rt.artifact(&bucket)?;
        let t = art.meta.t;
        let mut padded = vec![self.tok.pad as i32; t];
        padded[..tokens.len()].copy_from_slice(tokens);
        let lens = [tokens.len() as i32];
        let outs = self.rt.exec(&art, &[Arg::I32(&padded, &[1, t]), Arg::I32(&lens, &[1])])?;
        let si = art.meta.output_index("s")?;
        let pi = art.meta.output_index("s_plus")?;
        Ok((
            self.rt.fetch_f32(&outs[si], &art.meta.outputs[si].shape)?,
            self.rt.fetch_f32(&outs[pi], &art.meta.outputs[pi].shape)?,
        ))
    }

    /// Teacher-forced answer scoring: mean NLL (nats/byte) of `answer`
    /// given `prompt` under the pruned cache. This is the smooth quality
    /// metric the benches report alongside exact-match accuracy — it
    /// degrades gracefully as pruning removes needed KV pairs, so the
    /// policy ranking is measurable at any model quality.
    pub fn score_answer(
        &self,
        prompt: &str,
        answer: &str,
        policy: &dyn PrunePolicy,
    ) -> Result<(f64, f64)> {
        let man = &self.rt.manifest;
        let (layers, heads, t_max) =
            (man.model.n_layers, man.model.n_kv_heads, man.model.t_max);
        let toks = self.tok.encode(prompt, self.max_prompt());
        let n = toks.len();
        let ans: Vec<i32> = answer.bytes().map(|b| b as i32).collect();
        let bucket = man
            .prefill_bucket(n, 1)
            .ok_or_else(|| anyhow!("no prefill bucket for {n}"))?;
        let pf = self.rt.artifact(&bucket)?;
        let pt = pf.meta.t;
        let mut tok_flat = vec![self.tok.pad as i32; pt];
        tok_flat[..n].copy_from_slice(&toks);
        let lens = [n as i32];
        let outs =
            self.rt.exec(&pf, &[Arg::I32(&tok_flat, &[1, pt]), Arg::I32(&lens, &[1])])?;
        let fetch = |name: &str| -> Result<Tensor> {
            let i = pf.meta.output_index(name)?;
            self.rt.fetch_f32(&outs[i], &pf.meta.outputs[i].shape)
        };
        let logits0 = fetch("logits")?;
        let stats = PrefillStats {
            score_lin: fetch("score_lin")?,
            score_mlp: fetch("score_mlp")?,
            max_attn: fetch("max_attn")?,
            plus_attn: fetch("plus_attn")?,
            cum_attn: fetch("cum_attn")?,
            win_attn: fetch("win_attn")?,
            vnorm: fetch("vnorm")?,
            knorm: fetch("knorm")?,
        };
        let oracle = if policy.needs_oracle() {
            Some(self.oracle_scores(&toks)?)
        } else {
            None
        };
        let mut cache = PagedKvCache::new(layers, heads, t_max);
        cache.fill(n);
        policy.prefill_prune(&stats.view(0, oracle.as_ref()), n, &mut cache);
        let compression = cache.stats().compression();

        let ki = pf.meta.output_index("kcache")?;
        let vi = pf.meta.output_index("vcache")?;
        let mut outs_opt: Vec<Option<crate::runtime::Buffer>> =
            outs.into_iter().map(Some).collect();
        let mut kc = outs_opt[ki].take().unwrap();
        let mut vc = outs_opt[vi].take().unwrap();
        drop(outs_opt);

        let dec = self.rt.artifact(&man.decode_bucket(1).unwrap())?;
        let mut mask = cache.mask_f32();

        // NLL of answer byte i under logits from step i-1 (teacher forcing).
        let mut nll = 0.0f64;
        let mut count = 0usize;
        let mut logits = logits0;
        for (i, &a) in ans.iter().enumerate() {
            nll += nll_of(logits.row(&[0]), a);
            count += 1;
            let pos = n + i;
            if pos >= t_max || i == ans.len() - 1 {
                break;
            }
            // previously fed answer tokens become attendable
            if i > 0 {
                for l in 0..layers {
                    for h in 0..heads {
                        mask[(l * heads + h) * t_max + pos - 1] = 1.0;
                    }
                }
            }
            let mask_buf = self.rt.upload_f32(&mask, &[layers, 1, heads, t_max])?;
            let outs = self.rt.exec(
                &dec,
                &[
                    Arg::I32(&[a], &[1]),
                    Arg::I32(&[pos as i32], &[1]),
                    Arg::Buf(&kc),
                    Arg::Buf(&vc),
                    Arg::Buf(&mask_buf),
                ],
            )?;
            let li = dec.meta.output_index("logits")?;
            logits = self.rt.fetch_f32(&outs[li], &dec.meta.outputs[li].shape)?;
            let ki = dec.meta.output_index("kcache")?;
            let vi = dec.meta.output_index("vcache")?;
            let mut o: Vec<Option<crate::runtime::Buffer>> =
                outs.into_iter().map(Some).collect();
            kc = o[ki].take().unwrap();
            vc = o[vi].take().unwrap();
        }
        Ok((nll / count.max(1) as f64, compression))
    }
}
