//! Multi-shard router/coordinator: N engine workers behind one front door.
//!
//! Three pieces compose the layer:
//!
//! * [`PrefixCache`] — cross-request prefix reuse. The first request for a
//!   (prompt, policy) pair runs a real prefill and deposits a pruned
//!   post-KVzap snapshot ([`PrefillSnapshot`], captured *before* the first
//!   token is sampled); later requests for the same pair install the
//!   snapshot instead of re-running the prefill bucket. Because KVzap
//!   scoring is query-agnostic (KVzip §3.2: the surrogate scores depend
//!   only on the prompt), the pruned prefix is valid for any continuation,
//!   and because the per-request sampler still draws the first token from
//!   the stored logits row, outputs are bitwise identical to a fresh
//!   prefill. Hits and misses are accounted on
//!   [`crate::metrics::EngineMetrics`].
//! * [`Router`] — placement. A consistent-hash ring (virtual nodes per
//!   shard) gives every prompt a stable home shard; placements are sticky
//!   — once a key is placed, it only moves through a *recorded*
//!   [`Rebalance`] (load-based spill when the home shard's backlog runs
//!   ahead of the least-loaded shard). The simulation harness snapshots
//!   the placement table every step and fails the run if a placement
//!   changed without a matching rebalance record.
//! * [`ShardPool`] — the deterministic driver: owns one [`SchedCore`]
//!   (and thus one engine + resident cache) per shard, per-tenant FIFO
//!   queues pumped round-robin (at most one dispatch per tenant per
//!   round, bounded by a per-tenant in-flight cap), and steps shards in
//!   index order so a fixed submit schedule yields bit-identical token
//!   streams at any shard count.
//!
//! The threaded server reuses [`Router`] + [`PrefixCache`] directly (one
//! `Batcher` per shard); [`ShardPool`] is the single-threaded composition
//! used by the simulation harness and the saturation bench.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::batcher::{BatcherConfig, Request, Response, SchedCore, SeqEvent};
use super::engine::{Engine, PrefillSnapshot};

/// FNV-1a: tiny, deterministic, dependency-free — placement only ever
/// needs a stable well-mixed 64-bit digest, not collision resistance.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Point-in-time [`PrefixCache`] telemetry: lifetime counters plus the
/// current footprint. Counters are monotone; `bytes`/`entries` are
/// gauges read under the map lock.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Lookups that found a snapshot.
    pub hits: u64,
    /// Lookups that found nothing.
    pub misses: u64,
    /// Snapshots evicted to make room under the bytes budget.
    pub evictions: u64,
    /// Inserts that lost a key race (first writer wins; the newer
    /// snapshot was discarded).
    pub insert_races: u64,
    /// Inserts refused outright: the snapshot could not fit the budget
    /// even after evicting every cold entry.
    pub insert_rejects: u64,
    /// Host bytes currently held across all snapshots.
    pub bytes: usize,
    /// Snapshots currently held.
    pub entries: usize,
}

/// What one [`PrefixCache::insert`] did — the caller (the batcher)
/// forwards this to its engine's metrics so eviction churn is
/// attributed to the shard that caused it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixInsertOutcome {
    /// The snapshot entered the cache.
    pub installed: bool,
    /// The key was already present: this insert lost the race and its
    /// snapshot was discarded (first writer wins).
    pub raced: bool,
    /// Entries evicted to make room.
    pub evicted: usize,
    /// The snapshot did not fit the budget even after evicting every
    /// unpinned entry, and was not cached.
    pub rejected: bool,
}

struct PrefixEntry {
    snap: Arc<PrefillSnapshot>,
    bytes: usize,
    /// Monotone recency tick; refreshed on every hit (touch-on-hit LRU).
    last_used: u64,
}

struct PrefixMap {
    map: HashMap<(String, String), PrefixEntry>,
    /// Running byte total — kept exact on every insert/evict so readers
    /// never walk the map under the lock (the old O(n) `approx_bytes`).
    bytes: usize,
    tick: u64,
}

/// Shared store of pruned prefill snapshots keyed by (prompt, policy),
/// bounded by an optional bytes budget with LRU eviction.
///
/// Thread-safe (the threaded server shares one across shard batchers);
/// first writer wins so concurrent misses for the same key converge on a
/// single snapshot. Snapshots are deterministic in (prompt, policy) —
/// the reference backend's weights are seed-derived — so which shard
/// deposited one never matters.
///
/// Under a finite budget, `insert` evicts least-recently-used entries
/// until the newcomer fits. An entry whose snapshot is still referenced
/// outside the cache (`Arc` strong count > 1 — an install in flight on
/// some shard) is *pinned* and never evicted; a hit handed out stays
/// valid even if its entry is later evicted, because eviction only drops
/// the cache's own reference. If the newcomer cannot fit even after all
/// unpinned entries are gone, it is refused (counted as an
/// `insert_reject`) rather than blowing the budget.
#[derive(Default)]
pub struct PrefixCache {
    inner: Mutex<PrefixMap>,
    budget: Option<usize>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    insert_races: AtomicU64,
    insert_rejects: AtomicU64,
}

impl Default for PrefixMap {
    fn default() -> Self {
        PrefixMap { map: HashMap::new(), bytes: 0, tick: 0 }
    }
}

impl PrefixCache {
    /// An empty cache with no bytes budget (never evicts).
    pub fn new() -> PrefixCache {
        PrefixCache::default()
    }

    /// An empty cache holding at most `budget` snapshot bytes
    /// (`None` → unbounded, same as [`PrefixCache::new`]).
    pub fn with_budget(budget: Option<usize>) -> PrefixCache {
        PrefixCache { budget, ..PrefixCache::default() }
    }

    /// The configured bytes budget, if any.
    pub fn budget(&self) -> Option<usize> {
        self.budget
    }

    /// The snapshot for (prompt, policy), if one was deposited. Counts a
    /// hit or miss and refreshes the entry's LRU recency.
    pub fn lookup(&self, prompt: &str, policy: &str) -> Option<Arc<PrefillSnapshot>> {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        match g.map.get_mut(&(prompt.to_string(), policy.to_string())) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.snap.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Deposit a snapshot for (prompt, policy). First writer wins: a key
    /// collision discards `snap` and counts an `insert_race`. Under a
    /// finite budget, evicts cold unpinned entries (oldest `last_used`
    /// first) until the newcomer fits, or refuses it if it cannot fit.
    pub fn insert(
        &self,
        prompt: &str,
        policy: &str,
        snap: PrefillSnapshot,
    ) -> PrefixInsertOutcome {
        let key = (prompt.to_string(), policy.to_string());
        let bytes = snap.approx_bytes();
        let mut g = self.inner.lock().unwrap();
        if g.map.contains_key(&key) {
            self.insert_races.fetch_add(1, Ordering::Relaxed);
            return PrefixInsertOutcome { raced: true, ..Default::default() };
        }
        let mut evicted = 0usize;
        if let Some(budget) = self.budget {
            if bytes > budget {
                // can never fit — refuse up front rather than flushing
                // the whole cache first
                self.insert_rejects.fetch_add(1, Ordering::Relaxed);
                return PrefixInsertOutcome { rejected: true, ..Default::default() };
            }
            while g.bytes + bytes > budget {
                // coldest unpinned entry; a strong count above 1 means a
                // shard is mid-install from this snapshot — skip it
                let victim = g
                    .map
                    .iter()
                    .filter(|(_, e)| Arc::strong_count(&e.snap) == 1)
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| k.clone());
                let Some(vk) = victim else { break };
                let e = g.map.remove(&vk).unwrap();
                g.bytes -= e.bytes;
                evicted += 1;
            }
            if g.bytes + bytes > budget {
                // roll the evictions back into the counter anyway — they
                // happened — but refuse the newcomer
                self.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
                self.insert_rejects.fetch_add(1, Ordering::Relaxed);
                return PrefixInsertOutcome {
                    evicted,
                    rejected: true,
                    ..Default::default()
                };
            }
        }
        g.tick += 1;
        let tick = g.tick;
        g.map.insert(key, PrefixEntry { snap: Arc::new(snap), bytes, last_used: tick });
        g.bytes += bytes;
        self.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        PrefixInsertOutcome { installed: true, evicted, ..Default::default() }
    }

    /// Whether a snapshot exists for (prompt, policy). A peek: counts
    /// nothing and does not touch recency.
    pub fn contains(&self, prompt: &str, policy: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .map
            .contains_key(&(prompt.to_string(), policy.to_string()))
    }

    /// Number of cached snapshots.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when no snapshot has been deposited yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Host bytes held across all snapshots — O(1), read from the
    /// running counter rather than walking the map.
    pub fn approx_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Telemetry snapshot (counters + current footprint).
    pub fn stats(&self) -> PrefixCacheStats {
        let g = self.inner.lock().unwrap();
        PrefixCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            insert_races: self.insert_races.load(Ordering::Relaxed),
            insert_rejects: self.insert_rejects.load(Ordering::Relaxed),
            bytes: g.bytes,
            entries: g.map.len(),
        }
    }
}

/// Knobs for [`Router`] / [`ShardPool`].
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Number of engine workers.
    pub shards: usize,
    /// Ring points per shard (more → smoother key spread).
    pub virtual_nodes: usize,
    /// Backlog lead (placed shard minus least-loaded shard) at which a
    /// placement spills to the least-loaded shard.
    pub spill_threshold: usize,
    /// Per-shard backlog bound: the pump leaves a request queued (with a
    /// recorded "shard-full" skip) rather than dispatch to a shard at or
    /// above this backlog.
    pub shard_backlog: usize,
    /// Per-tenant in-flight cap across the pool (dispatched, unfinished).
    pub tenant_inflight: usize,
    /// Attach a shared [`PrefixCache`] to every shard.
    pub prefix_reuse: bool,
    /// Bytes budget for the shared prefix cache (`None` → unbounded).
    pub prefix_budget: Option<usize>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            shards: 1,
            virtual_nodes: 16,
            spill_threshold: 4,
            shard_backlog: 16,
            tenant_inflight: 8,
            prefix_reuse: false,
            prefix_budget: None,
        }
    }
}

/// One recorded placement change. Placements are immutable *except*
/// through these — the placement-stability invariant replays the table
/// and demands a matching record for every observed move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rebalance {
    /// [`Router::key_hash`] of the moved placement key.
    pub key_hash: u64,
    /// Shard the key was placed on before the move.
    pub from: usize,
    /// Shard the key moved to.
    pub to: usize,
    /// Why it moved (currently always "load-spill").
    pub cause: &'static str,
}

/// Consistent-hash placement with sticky assignments and load-based
/// spill. Deterministic: same key + same load vector → same shard.
pub struct Router {
    shards: usize,
    spill_threshold: usize,
    /// Sorted (point, shard) ring; `virtual_nodes` points per shard.
    ring: Vec<(u64, usize)>,
    /// key hash → shard, for every key ever placed.
    placements: HashMap<u64, usize>,
    rebalances: Vec<Rebalance>,
}

impl Router {
    /// A router over `cfg.shards` shards.
    pub fn new(cfg: &RouterConfig) -> Router {
        let shards = cfg.shards.max(1);
        let mut ring = Vec::with_capacity(shards * cfg.virtual_nodes.max(1));
        for s in 0..shards {
            for v in 0..cfg.virtual_nodes.max(1) {
                ring.push((fnv1a(format!("shard{s}/vnode{v}").as_bytes()), s));
            }
        }
        ring.sort_unstable();
        Router {
            shards,
            spill_threshold: cfg.spill_threshold.max(1),
            ring,
            placements: HashMap::new(),
            rebalances: vec![],
        }
    }

    /// The stable digest placement records are keyed by.
    pub fn key_hash(key: &str) -> u64 {
        fnv1a(key.as_bytes())
    }

    /// Number of shards this router places over.
    pub fn shards(&self) -> usize {
        self.shards
    }

    fn ring_shard(&self, h: u64) -> usize {
        let i = self.ring.partition_point(|&(p, _)| p < h);
        self.ring[i % self.ring.len()].1
    }

    /// Place `key` given the current per-shard backlogs. Sticky: a placed
    /// key stays put unless its shard's backlog leads the least-loaded
    /// shard by at least the spill threshold, in which case it moves there
    /// and the move is recorded as a [`Rebalance`]. A first placement may
    /// also spill (no record — nothing moved).
    pub fn place(&mut self, key: &str, loads: &[usize]) -> usize {
        debug_assert_eq!(loads.len(), self.shards);
        let h = fnv1a(key.as_bytes());
        let least = (0..self.shards).min_by_key(|&s| (loads[s], s)).unwrap_or(0);
        match self.placements.get(&h).copied() {
            Some(cur) => {
                if loads[cur] >= loads[least] + self.spill_threshold {
                    self.rebalances.push(Rebalance {
                        key_hash: h,
                        from: cur,
                        to: least,
                        cause: "load-spill",
                    });
                    self.placements.insert(h, least);
                    least
                } else {
                    cur
                }
            }
            None => {
                let home = self.ring_shard(h);
                let s = if loads[home] >= loads[least] + self.spill_threshold {
                    least
                } else {
                    home
                };
                self.placements.insert(h, s);
                s
            }
        }
    }

    /// Every placement ever made (key hash → shard).
    pub fn placements(&self) -> &HashMap<u64, usize> {
        &self.placements
    }

    /// All recorded placement moves, oldest first.
    pub fn rebalances(&self) -> &[Rebalance] {
        &self.rebalances
    }

    /// Fault hook (simulation only): silently move one placement record to
    /// the next shard *without* recording a rebalance — the defect the
    /// placement-stability invariant exists to catch. Deterministic (the
    /// smallest key hash moves). Returns false when there is nothing to
    /// misroute (no placements, or a single shard where every "move" is a
    /// no-op).
    pub fn inject_misroute(&mut self) -> bool {
        if self.shards < 2 {
            return false;
        }
        let Some(&h) = self.placements.keys().min() else {
            return false;
        };
        let cur = self.placements[&h];
        self.placements.insert(h, (cur + 1) % self.shards);
        true
    }
}

/// Why a backlogged tenant was passed over in one pump round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Skip {
    /// Pump round the skip happened in.
    pub round: u64,
    /// The tenant that was passed over.
    pub tenant: String,
    /// "inflight-cap" or "shard-full".
    pub cause: &'static str,
}

/// One request dispatched from the fair queue into a shard.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dispatch {
    /// Pump round the dispatch happened in.
    pub round: u64,
    /// The dispatching tenant.
    pub tenant: String,
    /// Request id.
    pub id: u64,
    /// Destination shard.
    pub shard: usize,
}

struct Queued {
    id: u64,
    req: Request,
}

/// N [`SchedCore`] workers behind a [`Router`] and per-tenant fair-share
/// queues. Single-threaded and deterministic: shards are stepped in index
/// order, tenants pumped in first-seen order, so a fixed submit schedule
/// produces bit-identical token streams at any shard count.
pub struct ShardPool {
    cores: Vec<SchedCore>,
    router: Router,
    prefix: Option<Arc<PrefixCache>>,
    shard_backlog: usize,
    tenant_inflight: usize,
    /// Tenants in first-seen order — the deterministic round-robin order.
    tenant_order: Vec<String>,
    queues: HashMap<String, VecDeque<Queued>>,
    inflight: HashMap<String, usize>,
    /// Dispatched-but-unfinished: id → (tenant, shard).
    id_map: HashMap<u64, (String, usize)>,
    /// Ids cancelled before they were submitted (mirrors
    /// [`SchedCore`]'s cancel-before-submit memory at the pool layer).
    pre_cancelled: std::collections::HashSet<u64>,
    skips: Vec<Skip>,
    dispatches: Vec<Dispatch>,
    round: u64,
}

impl ShardPool {
    /// A pool with one scheduler per engine. Every engine should have its
    /// own [`crate::runtime::Runtime`] (its own resident cache); sharing
    /// one runtime across shards works but serializes their caches.
    pub fn new(engines: Vec<Arc<Engine>>, batch: BatcherConfig, cfg: RouterConfig) -> ShardPool {
        assert!(!engines.is_empty(), "shard pool needs at least one engine");
        let prefix =
            cfg.prefix_reuse.then(|| Arc::new(PrefixCache::with_budget(cfg.prefix_budget)));
        let cores: Vec<SchedCore> = engines
            .into_iter()
            .map(|e| {
                let mut c = SchedCore::new(e, batch.clone());
                c.set_prefix_cache(prefix.clone());
                c
            })
            .collect();
        let router = Router::new(&RouterConfig { shards: cores.len(), ..cfg.clone() });
        ShardPool {
            cores,
            router,
            prefix,
            shard_backlog: cfg.shard_backlog.max(1),
            tenant_inflight: cfg.tenant_inflight.max(1),
            tenant_order: vec![],
            queues: HashMap::new(),
            inflight: HashMap::new(),
            id_map: HashMap::new(),
            pre_cancelled: std::collections::HashSet::new(),
            skips: vec![],
            dispatches: vec![],
            round: 0,
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.cores.len()
    }

    /// Shard `i`'s scheduler.
    pub fn core(&self, i: usize) -> &SchedCore {
        &self.cores[i]
    }

    /// Shard `i`'s scheduler, mutable (the harness drives admission and
    /// decode per shard itself to observe state between phases).
    pub fn core_mut(&mut self, i: usize) -> &mut SchedCore {
        &mut self.cores[i]
    }

    /// The placement router.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The placement router, mutable (fault hooks).
    pub fn router_mut(&mut self) -> &mut Router {
        &mut self.router
    }

    /// The shared prefix cache, when reuse is enabled.
    pub fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        self.prefix.as_ref()
    }

    /// Enqueue a request under `tenant` ("" is a tenant like any other).
    /// Ids must be unique among in-flight requests across the pool.
    pub fn submit(&mut self, id: u64, tenant: &str, req: Request) {
        if self.pre_cancelled.remove(&id) {
            let _ = req.events.send(SeqEvent::Done(Response {
                text: String::new(),
                compression: 0.0,
                tokens_out: 0,
                e2e_us: 0,
                error: None,
                reason: Some("cancelled".into()),
            }));
            return;
        }
        if !self.queues.contains_key(tenant) {
            self.tenant_order.push(tenant.to_string());
            self.queues.insert(tenant.to_string(), VecDeque::new());
        }
        self.queues.get_mut(tenant).unwrap().push_back(Queued { id, req });
    }

    /// Cancel a request wherever it currently lives: still queued here →
    /// answered immediately; dispatched → forwarded to its shard; not yet
    /// submitted → remembered, and answered at submit time.
    pub fn cancel(&mut self, id: u64) {
        for q in self.queues.values_mut() {
            if let Some(i) = q.iter().position(|p| p.id == id) {
                let p = q.remove(i).unwrap();
                let _ = p.req.events.send(SeqEvent::Done(Response {
                    text: String::new(),
                    compression: 0.0,
                    tokens_out: 0,
                    e2e_us: 0,
                    error: None,
                    reason: Some("cancelled".into()),
                }));
                return;
            }
        }
        if let Some(&(_, shard)) = self.id_map.get(&id) {
            self.cores[shard].cancel(id);
        } else {
            self.pre_cancelled.insert(id);
        }
    }

    /// Fair-share admission: round-robin over tenants in first-seen
    /// order, at most one dispatch per tenant per round, until a full
    /// round makes no progress. A tenant passed over while backlogged
    /// records a [`Skip`] with its cause (the fairness invariant demands
    /// one for every tenant still queued afterwards). Returns the number
    /// of requests dispatched.
    pub fn pump(&mut self) -> usize {
        let mut total = 0;
        loop {
            self.round += 1;
            let mut progress = false;
            for t in self.tenant_order.clone() {
                let Some(front) = self.queues.get(&t).and_then(|q| q.front()) else {
                    continue;
                };
                if self.inflight.get(&t).copied().unwrap_or(0) >= self.tenant_inflight {
                    self.skips.push(Skip {
                        round: self.round,
                        tenant: t.clone(),
                        cause: "inflight-cap",
                    });
                    continue;
                }
                let key = front.req.prompt.clone();
                let loads: Vec<usize> = self.cores.iter().map(|c| c.backlog()).collect();
                let shard = self.router.place(&key, &loads);
                if self.cores[shard].backlog() >= self.shard_backlog {
                    self.skips.push(Skip {
                        round: self.round,
                        tenant: t.clone(),
                        cause: "shard-full",
                    });
                    continue;
                }
                let p = self.queues.get_mut(&t).unwrap().pop_front().unwrap();
                self.cores[shard].submit(p.id, p.req);
                *self.inflight.entry(t.clone()).or_insert(0) += 1;
                self.id_map.insert(p.id, (t.clone(), shard));
                self.dispatches.push(Dispatch {
                    round: self.round,
                    tenant: t.clone(),
                    id: p.id,
                    shard,
                });
                progress = true;
                total += 1;
            }
            if !progress {
                break;
            }
        }
        total
    }

    /// Release per-tenant in-flight charges for finished request ids.
    pub fn note_finished(&mut self, ids: &[u64]) {
        for id in ids {
            if let Some((t, _)) = self.id_map.remove(id) {
                if let Some(n) = self.inflight.get_mut(&t) {
                    *n = n.saturating_sub(1);
                }
            }
        }
    }

    /// One full pool iteration: pump, then per shard (in index order)
    /// admit → reap → decode → reap, releasing in-flight charges as
    /// requests finish. Engine errors were already answered to the
    /// affected requests (same contract as [`SchedCore::step`]).
    pub fn step(&mut self) {
        self.pump();
        for i in 0..self.cores.len() {
            self.cores[i].admit_waiting();
            let mut done = self.cores[i].reap_finished();
            let _ = self.cores[i].decode_once();
            done.extend(self.cores[i].reap_finished());
            self.note_finished(&done);
        }
    }

    /// Requests still waiting in the pool's fair queues.
    pub fn queued(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// Tenants with a nonempty queue, in round-robin order.
    pub fn queued_tenants(&self) -> Vec<String> {
        self.tenant_order
            .iter()
            .filter(|t| self.queues.get(*t).is_some_and(|q| !q.is_empty()))
            .cloned()
            .collect()
    }

    /// No queued and no shard-resident work anywhere.
    pub fn is_idle(&self) -> bool {
        self.queued() == 0 && self.cores.iter().all(|c| c.is_idle())
    }

    /// Drain the skip records accumulated since the last call.
    pub fn take_skips(&mut self) -> Vec<Skip> {
        std::mem::take(&mut self.skips)
    }

    /// Drain the dispatch records accumulated since the last call.
    pub fn take_dispatches(&mut self) -> Vec<Dispatch> {
        std::mem::take(&mut self.dispatches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sampler::SamplingParams;
    use crate::policies::PolicySpec;
    use crate::runtime::Runtime;
    use std::sync::mpsc::{channel, Receiver};

    fn cfg(shards: usize) -> RouterConfig {
        RouterConfig { shards, ..Default::default() }
    }

    #[test]
    fn placement_is_sticky_and_deterministic() {
        let mut r1 = Router::new(&cfg(4));
        let mut r2 = Router::new(&cfg(4));
        let loads = [0usize; 4];
        let mut used = std::collections::HashSet::new();
        for i in 0..32 {
            let key = format!("prompt-{i}");
            let a = r1.place(&key, &loads);
            assert_eq!(a, r2.place(&key, &loads), "two routers agree");
            assert_eq!(a, r1.place(&key, &loads), "repeat placement is sticky");
            used.insert(a);
        }
        assert!(used.len() >= 2, "keys spread over shards: {used:?}");
        assert!(r1.rebalances().is_empty(), "no moves under balanced load");
    }

    #[test]
    fn overload_spills_and_records_rebalance() {
        let mut r = Router::new(&cfg(2));
        let home = r.place("k", &[0, 0]);
        let other = 1 - home;
        // Re-place with the home shard far ahead: must spill and record.
        let mut loads = [0usize; 2];
        loads[home] = 10;
        let moved = r.place("k", &loads);
        assert_eq!(moved, other);
        assert_eq!(
            r.rebalances(),
            &[Rebalance {
                key_hash: Router::key_hash("k"),
                from: home,
                to: other,
                cause: "load-spill"
            }]
        );
        // Sticky again on the new shard under balanced load.
        assert_eq!(r.place("k", &[1, 1]), other);
        assert_eq!(r.rebalances().len(), 1);
    }

    #[test]
    fn misroute_injection_moves_a_placement_without_a_record() {
        let mut r = Router::new(&cfg(2));
        assert!(!r.inject_misroute(), "nothing placed yet");
        let before = r.place("k", &[0, 0]);
        assert!(r.inject_misroute());
        let after = r.placements()[&Router::key_hash("k")];
        assert_ne!(before, after);
        assert!(r.rebalances().is_empty(), "the fault leaves no record");
    }

    #[test]
    fn prefix_cache_starts_empty() {
        let pc = PrefixCache::new();
        assert!(pc.is_empty());
        assert!(pc.lookup("p", "full").is_none());
        assert!(!pc.contains("p", "full"));
        assert_eq!(pc.approx_bytes(), 0);
        let st = pc.stats();
        assert_eq!(st.misses, 1, "the empty lookup counted a miss");
        assert_eq!((st.hits, st.evictions, st.insert_races, st.bytes), (0, 0, 0, 0));
    }

    /// Bounded LRU mechanics: the running bytes counter stays exact and
    /// ≤ budget, inserts evict coldest-first, and a hit refreshes recency
    /// so the touched entry survives the next eviction.
    #[test]
    fn prefix_cache_evicts_lru_under_bytes_budget() {
        // room for exactly two 400-byte snapshots
        let pc = PrefixCache::with_budget(Some(800));
        assert_eq!(pc.budget(), Some(800));
        assert!(pc.insert("a", "full", PrefillSnapshot::test_stub(400)).installed);
        assert!(pc.insert("b", "full", PrefillSnapshot::test_stub(400)).installed);
        assert_eq!((pc.len(), pc.approx_bytes()), (2, 800));
        // touch "a" so "b" is now the coldest entry
        assert!(pc.lookup("a", "full").is_some());
        let out = pc.insert("c", "full", PrefillSnapshot::test_stub(400));
        assert!(out.installed);
        assert_eq!(out.evicted, 1, "one cold entry made room");
        assert!(pc.contains("a", "full"), "the touched entry survived");
        assert!(!pc.contains("b", "full"), "the cold entry was evicted");
        assert!(pc.contains("c", "full"));
        assert_eq!((pc.len(), pc.approx_bytes()), (2, 800));
        let st = pc.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.bytes, 800);
        assert!(st.bytes <= 800, "bytes never exceed the budget");
    }

    /// A snapshot handed out by `lookup` pins its entry: eviction skips
    /// it while the install is in flight, and the newcomer evicts the
    /// next-coldest unpinned entry instead. An entry too large to ever
    /// fit is refused, not admitted over budget.
    #[test]
    fn prefix_cache_pins_in_flight_installs_and_rejects_oversize() {
        let pc = PrefixCache::with_budget(Some(800));
        assert!(pc.insert("a", "full", PrefillSnapshot::test_stub(400)).installed);
        assert!(pc.insert("b", "full", PrefillSnapshot::test_stub(400)).installed);
        // hold "a" like a shard mid-install; "b" is hotter but unpinned
        let pinned = pc.lookup("a", "full").unwrap();
        assert!(pc.lookup("b", "full").is_some());
        let out = pc.insert("c", "full", PrefillSnapshot::test_stub(400));
        assert!(out.installed);
        assert!(pc.contains("a", "full"), "pinned entry never evicted");
        assert!(!pc.contains("b", "full"), "hotter but unpinned entry paid instead");
        drop(pinned);
        // a snapshot larger than the whole budget is refused outright
        let out = pc.insert("d", "full", PrefillSnapshot::test_stub(2000));
        assert!(out.rejected && !out.installed);
        assert!(!pc.contains("d", "full"));
        assert!(pc.approx_bytes() <= 800);
        assert_eq!(pc.stats().insert_rejects, 1);
    }

    /// Concurrent inserts for one key: first writer wins, every loser is
    /// counted as an `insert_race`, and exactly one snapshot survives.
    #[test]
    fn prefix_cache_counts_insert_races_under_concurrency() {
        let pc = Arc::new(PrefixCache::new());
        let n = 8;
        let mut handles = vec![];
        for _ in 0..n {
            let pc = pc.clone();
            handles.push(std::thread::spawn(move || {
                pc.insert("shared", "full", PrefillSnapshot::test_stub(100))
            }));
        }
        let outs: Vec<PrefixInsertOutcome> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(outs.iter().filter(|o| o.installed).count(), 1, "one winner");
        assert_eq!(outs.iter().filter(|o| o.raced).count(), n - 1, "n-1 losers");
        assert_eq!(pc.len(), 1);
        assert_eq!(pc.approx_bytes(), 100);
        assert_eq!(pc.stats().insert_races, (n - 1) as u64);
    }

    fn request(prompt: &str) -> (Request, Receiver<SeqEvent>) {
        let (tx, rx) = channel();
        let req = Request {
            prompt: prompt.to_string(),
            policy: PolicySpec::Full,
            sp: SamplingParams { max_new: 4, greedy: true, seed: 1, ..Default::default() },
            stream: false,
            events: tx,
        };
        (req, rx)
    }

    fn pool(shards: usize, rcfg: RouterConfig) -> ShardPool {
        let engines = (0..shards)
            .map(|_| Arc::new(Engine::new(Arc::new(Runtime::reference_with_t_max(128)))))
            .collect();
        ShardPool::new(engines, BatcherConfig::default(), rcfg)
    }

    fn final_text(rx: &Receiver<SeqEvent>) -> String {
        loop {
            match rx.recv().expect("response") {
                SeqEvent::Done(r) => {
                    assert!(r.error.is_none(), "unexpected error: {:?}", r.error);
                    return r.text;
                }
                SeqEvent::Token { .. } => {}
            }
        }
    }

    /// Round-robin pump: with two backlogged tenants, each round
    /// dispatches at most one request per tenant, and a tenant blocked by
    /// its in-flight cap records a skip cause.
    #[test]
    fn pump_interleaves_tenants_and_records_skip_causes() {
        let rcfg = RouterConfig { tenant_inflight: 2, ..cfg(2) };
        let mut p = pool(2, rcfg);
        let mut rxs = vec![];
        for i in 0..4u64 {
            let (req, rx) = request(&format!("tenant-a request {i}"));
            p.submit(i, "a", req);
            rxs.push(rx);
        }
        for i in 4..6u64 {
            let (req, rx) = request(&format!("tenant-b request {i}"));
            p.submit(i, "b", req);
            rxs.push(rx);
        }
        let n = p.pump();
        assert_eq!(n, 4, "2 per tenant: both hit the in-flight cap of 2");
        let dispatches = p.take_dispatches();
        for round in [1u64, 2] {
            for tenant in ["a", "b"] {
                let k = dispatches
                    .iter()
                    .filter(|d| d.round == round && d.tenant == tenant)
                    .count();
                assert_eq!(k, 1, "round {round} tenant {tenant}: exactly one dispatch");
            }
        }
        let skips = p.take_skips();
        assert!(
            skips.iter().any(|s| s.tenant == "a" && s.cause == "inflight-cap"),
            "capped tenant records its skip cause: {skips:?}"
        );
        assert_eq!(p.queued(), 2);
        assert_eq!(p.queued_tenants(), vec!["a".to_string()]);
        // Drain to completion: caps release as requests finish.
        for _ in 0..200 {
            if p.is_idle() {
                break;
            }
            p.step();
        }
        assert!(p.is_idle(), "pool drains");
        for rx in &rxs {
            assert!(!final_text(rx).is_empty());
        }
    }

    /// Metamorphic composition check at the pool level: the same six
    /// requests produce bitwise-identical texts at 1 and 2 shards, and
    /// shared prompts hit the prefix cache without changing outputs.
    #[test]
    fn shard_count_and_prefix_reuse_preserve_outputs() {
        let run = |shards: usize, reuse: bool| -> Vec<String> {
            let rcfg = RouterConfig { prefix_reuse: reuse, ..cfg(shards) };
            let mut p = pool(shards, rcfg);
            let mut rxs = vec![];
            for i in 0..6u64 {
                // three distinct prompts, each submitted twice
                let (req, rx) = request(&format!("shared prompt {}", i % 3));
                p.submit(i, if i % 2 == 0 { "a" } else { "b" }, req);
                rxs.push(rx);
            }
            for _ in 0..200 {
                if p.is_idle() {
                    break;
                }
                p.step();
            }
            assert!(p.is_idle());
            if reuse {
                let pc = p.prefix_cache().expect("cache attached");
                assert_eq!(pc.len(), 3, "one snapshot per distinct prompt");
                let hits: u64 = (0..p.shard_count())
                    .map(|i| {
                        p.core(i)
                            .engine()
                            .metrics
                            .prefix_hits
                            .load(std::sync::atomic::Ordering::Relaxed)
                    })
                    .sum();
                assert_eq!(hits, 3, "each repeated prompt hits once");
            }
            rxs.iter().map(final_text).collect()
        };
        let base = run(1, false);
        assert_eq!(base, run(2, false), "shard count must not change outputs");
        assert_eq!(base, run(1, true), "prefix reuse must not change outputs");
        assert_eq!(base, run(2, true), "sharding + reuse must not change outputs");
    }
}
