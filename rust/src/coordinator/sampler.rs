//! Token sampling: greedy and temperature / top-k / top-p (the Qwen3
//! reasoning settings from paper §4.3: T=0.6, top-p=0.95, top-k=20).

use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SamplingParams {
    pub greedy: bool,
    pub temperature: f32,
    pub top_k: usize,
    pub top_p: f32,
    pub seed: u64,
    pub max_new: usize,
    /// Answers in the task grammar are newline-terminated.
    pub stop_at_newline: bool,
}

impl SamplingParams {
    pub fn greedy(max_new: usize) -> SamplingParams {
        SamplingParams {
            greedy: true,
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            max_new,
            stop_at_newline: true,
        }
    }

    /// The paper's reasoning sampling configuration (§4.3).
    pub fn reasoning(max_new: usize, seed: u64) -> SamplingParams {
        SamplingParams {
            greedy: false,
            temperature: 0.6,
            top_k: 20,
            top_p: 0.95,
            seed,
            max_new,
            stop_at_newline: false,
        }
    }

    /// Build from a request object's sampling fields (`max_new`, `greedy`,
    /// `temperature`, `top_k`, `top_p`, `seed`, `stop_newline`) — absent
    /// fields take the greedy defaults, matching the serving protocol.
    pub fn from_json(j: &Json) -> SamplingParams {
        let max_new = j.get("max_new").and_then(|v| v.as_usize()).unwrap_or(32);
        let greedy = j.get("greedy").and_then(|v| v.as_bool()).unwrap_or(true);
        let seed = j.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64;
        let mut sp = if greedy {
            SamplingParams::greedy(max_new)
        } else {
            SamplingParams::reasoning(max_new, seed)
        };
        sp.seed = seed;
        if let Some(t) = j.get("temperature").and_then(|v| v.as_f64()) {
            sp.temperature = t as f32;
        }
        if let Some(k) = j.get("top_k").and_then(|v| v.as_usize()) {
            sp.top_k = k;
        }
        if let Some(p) = j.get("top_p").and_then(|v| v.as_f64()) {
            sp.top_p = p as f32;
        }
        if let Some(b) = j.get("stop_newline").and_then(|v| v.as_bool()) {
            sp.stop_at_newline = b;
        }
        sp
    }
}

pub struct Sampler {
    rng: Rng,
}

impl Sampler {
    pub fn new(seed: u64) -> Sampler {
        Sampler { rng: Rng::new(seed) }
    }

    pub fn sample(&mut self, logits: &[f32], p: &SamplingParams) -> i32 {
        if p.greedy {
            return argmax(logits) as i32;
        }
        // temperature + top-k + top-p over a softmax
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        if p.top_k > 0 && p.top_k < idx.len() {
            idx.truncate(p.top_k);
        }
        let m = logits[idx[0]];
        let mut probs: Vec<f64> = idx
            .iter()
            .map(|&i| (((logits[i] - m) / p.temperature.max(1e-6)) as f64).exp())
            .collect();
        let sum: f64 = probs.iter().sum();
        for q in probs.iter_mut() {
            *q /= sum;
        }
        if p.top_p < 1.0 {
            let mut acc = 0.0;
            let mut cut = probs.len();
            for (i, &q) in probs.iter().enumerate() {
                acc += q;
                if acc >= p.top_p as f64 {
                    cut = i + 1;
                    break;
                }
            }
            probs.truncate(cut);
            idx.truncate(cut);
            let s: f64 = probs.iter().sum();
            for q in probs.iter_mut() {
                *q /= s;
            }
        }
        let mut u = self.rng.f64();
        for (i, &q) in probs.iter().enumerate() {
            if u < q {
                return idx[i] as i32;
            }
            u -= q;
        }
        *idx.last().unwrap() as i32
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(0);
        let logits = vec![0.1, 5.0, -1.0, 2.0];
        assert_eq!(s.sample(&logits, &SamplingParams::greedy(1)), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(1);
        let mut logits = vec![0.0f32; 16];
        logits[3] = 10.0;
        logits[7] = 9.0;
        let p = SamplingParams { greedy: false, temperature: 1.0, top_k: 2, top_p: 1.0, seed: 0, max_new: 1, stop_at_newline: false };
        for _ in 0..200 {
            let t = s.sample(&logits, &p);
            assert!(t == 3 || t == 7, "sampled {t}");
        }
    }

    #[test]
    fn temperature_zero_like_behaviour() {
        // very low temperature concentrates on the max
        let mut s = Sampler::new(2);
        let logits = vec![1.0, 1.2, 0.8];
        let p = SamplingParams { greedy: false, temperature: 0.01, top_k: 0, top_p: 1.0, seed: 0, max_new: 1, stop_at_newline: false };
        for _ in 0..50 {
            assert_eq!(s.sample(&logits, &p), 1);
        }
    }

    #[test]
    fn top_p_nucleus() {
        let mut s = Sampler::new(3);
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let p = SamplingParams { greedy: false, temperature: 1.0, top_k: 0, top_p: 0.5, seed: 0, max_new: 1, stop_at_newline: false };
        for _ in 0..100 {
            assert_eq!(s.sample(&logits, &p), 0);
        }
    }
}
