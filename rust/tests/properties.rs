//! Property tests over the coordinator substrates: ScoreBuffer (Algorithm
//! 1's delayed eviction), PagedKvCache accounting, block-pool residency,
//! and the byte tokenizer round-trip.
//!
//! Split from the original tests/integration.rs — same tests, same names.

mod common;

use std::sync::Arc;

use common::ramp_tensor;
use kvzap::kvcache::{BlockPool, PagedKvCache};
use kvzap::policies::{self, PrefillView, PrunePolicy, ScoreBuffer};
use kvzap::util::propcheck::{check, check_with, shrink_vec, Config};
use kvzap::util::rng::Rng;
use kvzap::workload;

// ---------------------------------------------------------------------------
// ScoreBuffer: Algorithm 1's delayed eviction (property tests)

/// The sliding window of the `w` most recent decoded positions is never
/// evicted, regardless of scores or threshold.
#[test]
fn prop_scorebuffer_window_never_evicted() {
    check(
        60,
        |r| {
            let w = r.below(12) + 2;
            let n = r.below(80) + w + 1;
            let tau = (r.f64() * 200.0 - 100.0) as f32;
            let scores: Vec<f32> =
                (0..n * 4).map(|_| (r.f64() * 20.0 - 10.0) as f32).collect();
            (w, n, tau, scores)
        },
        |&(w, n, tau, ref scores)| {
            let mut cache = PagedKvCache::new(2, 2, 256);
            let mut buf = ScoreBuffer::new(w, 2, 2);
            for i in 0..n {
                cache.fill(i + 1);
                buf.push_and_evict(i, scores[i * 4..(i + 1) * 4].to_vec(), tau, &mut cache);
                for p in i.saturating_sub(w - 1)..=i {
                    for l in 0..2 {
                        for h in 0..2 {
                            if !cache.is_kept(l, h, p) {
                                return Err(format!(
                                    "in-window pos {p} evicted at step {i} (w={w} tau={tau})"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Decode-time eviction matches an oracle recomputation on random score
/// streams: position i ends up evicted in head (l, h) iff it left the
/// window (i + w < n) and its score fell below tau.
#[test]
fn prop_scorebuffer_matches_oracle_recomputation() {
    check(
        60,
        |r| {
            let w = r.below(10) + 2;
            let n = r.below(100) + 1;
            let tau = (r.f64() * 12.0 - 6.0) as f32;
            let scores: Vec<f32> =
                (0..n * 4).map(|_| (r.f64() * 20.0 - 10.0) as f32).collect();
            (w, n, tau, scores)
        },
        |&(w, n, tau, ref scores)| {
            let mut cache = PagedKvCache::new(2, 2, 256);
            let mut buf = ScoreBuffer::new(w, 2, 2);
            for i in 0..n {
                cache.fill(i + 1);
                buf.push_and_evict(i, scores[i * 4..(i + 1) * 4].to_vec(), tau, &mut cache);
            }
            for i in 0..n {
                for l in 0..2 {
                    for h in 0..2 {
                        let evicted = i + w < n && scores[i * 4 + l * 2 + h] < tau;
                        if cache.is_kept(l, h, i) != !evicted {
                            return Err(format!(
                                "pos {i} head ({l},{h}): kept={} oracle_evicted={evicted} \
                                 (w={w} n={n} tau={tau})",
                                cache.is_kept(l, h, i)
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Thresholding is monotone in tau: anything evicted at a lower threshold
/// is also evicted at a higher one (on the same score stream).
#[test]
fn prop_scorebuffer_thresholding_monotone_in_tau() {
    check(
        40,
        |r| {
            let w = r.below(8) + 2;
            let n = r.below(60) + w + 1;
            let a = r.f64() * 12.0 - 6.0;
            let b = r.f64() * 12.0 - 6.0;
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            let scores: Vec<f32> =
                (0..n * 4).map(|_| (r.f64() * 20.0 - 10.0) as f32).collect();
            (w, n, lo as f32, hi as f32, scores)
        },
        |&(w, n, lo, hi, ref scores)| {
            let run = |tau: f32| -> PagedKvCache {
                let mut cache = PagedKvCache::new(2, 2, 256);
                let mut buf = ScoreBuffer::new(w, 2, 2);
                for i in 0..n {
                    cache.fill(i + 1);
                    buf.push_and_evict(i, scores[i * 4..(i + 1) * 4].to_vec(), tau, &mut cache);
                }
                cache
            };
            let (clo, chi) = (run(lo), run(hi));
            if clo.stats().kept < chi.stats().kept {
                return Err(format!(
                    "higher tau kept more: {} (tau={lo}) vs {} (tau={hi})",
                    clo.stats().kept,
                    chi.stats().kept
                ));
            }
            for i in 0..n {
                for l in 0..2 {
                    for h in 0..2 {
                        if !clo.is_kept(l, h, i) && chi.is_kept(l, h, i) {
                            return Err(format!(
                                "pos {i} ({l},{h}) evicted at tau={lo} but kept at tau={hi}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// PagedKvCache invariants (property tests)

#[test]
fn prop_budget_policies_meet_budget() {
    check(
        40,
        |r| {
            (
                r.below(4) + 1,                   // layers
                r.below(3) + 1,                   // heads
                r.below(200) + 40,                // prompt len
                [0.25, 0.5, 0.75][r.below(3)],    // keep frac
                r.next_u64(),
            )
        },
        |&(l, h, n, frac, seed)| {
            let mut rng = Rng::new(seed);
            let t = ramp_tensor(l, h, 256, &mut rng);
            let view = PrefillView {
                b: 0,
                score_lin: &t, score_mlp: &t, max_attn: &t, plus_attn: &t,
                cum_attn: &t, win_attn: &t, vnorm: &t, knorm: &t,
                oracle_s: Some(&t), oracle_s_plus: Some(&t),
            };
            for spec in ["h2o", "snapkv", "adakv", "kvzip", "knorm"] {
                let pol = policies::by_name(&format!("{spec}:{frac}"), 8).unwrap();
                let mut cache = PagedKvCache::new(l, h, 256);
                cache.fill(n);
                pol.prefill_prune(&view, n, &mut cache);
                let s = cache.stats();
                let kept_frac = s.kept as f64 / s.filled as f64;
                // budget ± window slack
                let slack = (8.0 + 2.0) / n as f64;
                if (kept_frac - frac).abs() > slack + 0.05 {
                    return Err(format!(
                        "{spec}: kept {kept_frac:.3} vs budget {frac} (l={l} h={h} n={n})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_window_always_protected() {
    check(
        40,
        |r| (r.below(150) + 30, r.next_u64(), [-100.0f32, 0.0, 100.0][r.below(3)]),
        |&(n, seed, tau)| {
            let mut rng = Rng::new(seed);
            let t = ramp_tensor(2, 2, 256, &mut rng);
            let view = PrefillView {
                b: 0,
                score_lin: &t, score_mlp: &t, max_attn: &t, plus_attn: &t,
                cum_attn: &t, win_attn: &t, vnorm: &t, knorm: &t,
                oracle_s: None, oracle_s_plus: None,
            };
            let window = 8;
            let pol = policies::KVzap::mlp(tau, window);
            let mut cache = PagedKvCache::new(2, 2, 256);
            cache.fill(n);
            pol.prefill_prune(&view, n, &mut cache);
            for l in 0..2 {
                for h in 0..2 {
                    for pos in n.saturating_sub(window)..n {
                        if !cache.is_kept(l, h, pos) {
                            return Err(format!("window pos {pos} evicted (n={n})"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_accounting_consistent() {
    check_with(
        Config { cases: 60, seed: 0xFEED },
        |r| {
            let n = r.below(120) + 16;
            let evictions: Vec<(usize, usize, usize)> = (0..r.below(200))
                .map(|_| (r.below(2), r.below(2), r.below(n)))
                .collect();
            (n, evictions)
        },
        |(n, ev)| {
            vec![(*n, shrink_vec(ev).pop().unwrap_or_default())]
        },
        |(n, evictions)| {
            let mut cache = PagedKvCache::new(2, 2, 256);
            cache.fill(*n);
            let mut expect = std::collections::HashSet::new();
            for &(l, h, p) in evictions {
                cache.evict(l, h, p);
                expect.insert((l, h, p));
            }
            let s = cache.stats();
            let want_kept = 2 * 2 * n - expect.len();
            if s.kept != want_kept {
                return Err(format!("kept {} want {}", s.kept, want_kept));
            }
            // mask agrees
            let mask = cache.mask_f32();
            let on = mask.iter().filter(|&&m| m > 0.0).count();
            if on != want_kept {
                return Err(format!("mask on {} want {}", on, want_kept));
            }
            Ok(())
        },
    );
}

/// retain/evict/fill vs CacheStats.compression() and the position-wise
/// mask_f32 round-trip, against a brute-force mirror of the kept set.
#[test]
fn prop_cache_retain_fill_mask_roundtrip() {
    check_with(
        Config { cases: 50, seed: 0xCAFE },
        |r| {
            let n = r.below(100) + 10;
            let grow = r.below(20);
            let modulus = r.below(5) + 2;
            let evictions: Vec<(usize, usize, usize)> = (0..r.below(100))
                .map(|_| (r.below(2), r.below(3), r.below(n + grow)))
                .collect();
            (n, grow, modulus, evictions)
        },
        |(n, grow, modulus, ev)| {
            vec![(*n, *grow, *modulus, shrink_vec(ev).pop().unwrap_or_default())]
        },
        |&(n, grow, modulus, ref evictions)| {
            let (layers, heads, t_max) = (2usize, 3usize, 160usize);
            let mut cache = PagedKvCache::new(layers, heads, t_max);
            let mut mirror = vec![false; layers * heads * t_max];
            cache.fill(n);
            for l in 0..layers {
                for h in 0..heads {
                    for p in 0..n {
                        mirror[(l * heads + h) * t_max + p] = true;
                    }
                }
            }
            // retain a modular pattern on head (0, 0)
            cache.retain(0, 0, n, |p| p % modulus == 0);
            for p in 0..n {
                if p % modulus != 0 {
                    mirror[p] = false;
                }
            }
            // grow the cache (decode fills), then apply random evictions
            cache.fill(n + grow);
            for l in 0..layers {
                for h in 0..heads {
                    for p in n..n + grow {
                        mirror[(l * heads + h) * t_max + p] = true;
                    }
                }
            }
            for &(l, h, p) in evictions {
                cache.evict(l, h, p);
                if p < n + grow {
                    mirror[(l * heads + h) * t_max + p] = false;
                }
            }
            // position-wise agreement: is_kept == mask_f32 == mirror
            let mask = cache.mask_f32();
            for l in 0..layers {
                for h in 0..heads {
                    for p in 0..t_max {
                        let i = (l * heads + h) * t_max + p;
                        if mirror[i] != cache.is_kept(l, h, p) {
                            return Err(format!("is_kept mismatch at ({l},{h},{p})"));
                        }
                        if mirror[i] != (mask[i] > 0.0) {
                            return Err(format!("mask mismatch at ({l},{h},{p})"));
                        }
                    }
                }
            }
            // aggregate accounting
            let kept = mirror.iter().filter(|&&k| k).count();
            let s = cache.stats();
            if s.kept != kept {
                return Err(format!("stats.kept {} want {kept}", s.kept));
            }
            if s.filled != layers * heads * (n + grow) {
                return Err(format!("stats.filled {}", s.filled));
            }
            let want_comp = 1.0 - kept as f64 / s.filled as f64;
            if (s.compression() - want_comp).abs() > 1e-12 {
                return Err(format!("compression {} want {want_comp}", s.compression()));
            }
            // per-head counts sum to the total
            let sum: usize = (0..layers)
                .flat_map(|l| (0..heads).map(move |h| (l, h)))
                .map(|(l, h)| cache.kept_in_head(l, h))
                .sum();
            if sum != kept {
                return Err(format!("kept_in_head sum {sum} want {kept}"));
            }
            Ok(())
        },
    );
}

/// `retain` is extensionally equal to the per-position `evict` loop it
/// batches: identical kept bits, mask, aggregate stats (including block
/// reclamation) and pool residency on every random keep pattern. The
/// prefill prune path goes through `retain` while decode eviction goes
/// through `evict` — if the two ever diverge, prefill and decode would
/// disagree about what the cache holds.
#[test]
fn prop_retain_equals_per_position_evict_loop() {
    check(
        60,
        |r| {
            let layers = r.below(2) + 1;
            let heads = r.below(3) + 1;
            let n = r.below(120) + 8;
            let l = r.below(layers);
            let h = r.below(heads);
            let keep: Vec<bool> = (0..n).map(|_| r.below(3) > 0).collect();
            (layers, heads, n, l, h, keep)
        },
        |&(layers, heads, n, l, h, ref keep)| {
            let pool_a = Arc::new(BlockPool::new(256));
            let pool_b = Arc::new(BlockPool::new(256));
            let mut a = PagedKvCache::new(layers, heads, 160).with_pool(pool_a.clone());
            let mut b = PagedKvCache::new(layers, heads, 160).with_pool(pool_b.clone());
            a.fill(n);
            b.fill(n);
            a.retain(l, h, n, |p| keep[p]);
            for p in 0..n {
                if !keep[p] {
                    b.evict(l, h, p);
                }
            }
            if a.stats() != b.stats() {
                return Err(format!(
                    "stats diverged: retain {:?} vs evict loop {:?}",
                    a.stats(),
                    b.stats()
                ));
            }
            if a.mask_f32() != b.mask_f32() {
                return Err("mask diverged".into());
            }
            for ll in 0..layers {
                for hh in 0..heads {
                    for p in 0..n {
                        if a.is_kept(ll, hh, p) != b.is_kept(ll, hh, p) {
                            return Err(format!("is_kept diverged at ({ll},{hh},{p})"));
                        }
                    }
                    if a.kept_in_head(ll, hh) != b.kept_in_head(ll, hh) {
                        return Err(format!("kept_in_head diverged at ({ll},{hh})"));
                    }
                }
            }
            if pool_a.used() != pool_b.used() {
                return Err(format!(
                    "pool residency diverged: {} vs {}",
                    pool_a.used(),
                    pool_b.used()
                ));
            }
            Ok(())
        },
    );
}

/// Block-pool accounting: blocks freed by whole-block eviction return to
/// the pool immediately, and everything is released on drop (`with_pool`).
#[test]
fn pool_blocks_released_on_eviction_and_drop() {
    let pool = Arc::new(BlockPool::new(64));
    {
        let mut c = PagedKvCache::new(2, 2, 256).with_pool(pool.clone());
        assert!(c.fill(40)); // ceil(40/16) = 3 blocks x 4 heads = 12
        assert_eq!(pool.used(), 12);
        for p in 0..16 {
            c.evict(0, 0, p); // empties block 0 of head (0, 0)
        }
        assert_eq!(pool.used(), 11, "whole-block eviction returns the block");
        assert_eq!(c.stats().freed_blocks, 1);
    }
    assert_eq!(pool.free(), 64, "drop releases all residency");
    assert_eq!(pool.used(), 0);
}

/// Memory governance: under random interleavings of fill / evict /
/// demote / rehydrate / drop-demoted traffic, every byte the cache holds
/// is charged to its pool and the charge never exceeds the budget — at
/// every step, under both the unified configuration (one byte pool for
/// both tiers) and the split configuration (block pool + side pool).
/// Refused operations (pool exhausted) must leave the accounting intact,
/// which is exactly the graceful demote-into-drop degradation the engine
/// relies on under pressure.
#[test]
fn prop_pool_charges_bounded_under_random_tier_traffic() {
    use kvzap::kvcache::{KvPools, TierConfig};
    use kvzap::runtime::kernels::QuantBits;

    let tier = TierConfig { d_head: 8, bits: QuantBits::Int8, group: 8 };
    let bb = tier.resident_block_bytes();
    let bpe = tier.bytes_per_entry();
    let (layers, heads, t_max) = (2usize, 2usize, 128usize);

    check(
        50,
        |r| {
            // Budgets sized so both admission and refusal paths are hit.
            let blocks = r.below(24) + 4;
            let side_entries = r.below(12) + 1;
            let ops: Vec<(usize, usize, usize, usize)> = (0..r.below(300) + 50)
                .map(|_| (r.below(5), r.below(2), r.below(2), r.below(200)))
                .collect();
            (blocks, side_entries, ops)
        },
        |&(blocks, side_entries, ref ops)| {
            let unified_total = blocks * bb + side_entries * bpe;
            for split in [false, true] {
                let upool = Arc::new(BlockPool::new(unified_total));
                let bpool = Arc::new(BlockPool::new(blocks));
                let spool = Arc::new(BlockPool::new(side_entries * bpe));
                let mut cache = PagedKvCache::new_tiered(layers, heads, t_max, tier);
                let pools = if split {
                    KvPools::Split { blocks: Some(bpool.clone()), side: Some(spool.clone()) }
                } else {
                    KvPools::Unified(upool.clone())
                };
                assert!(cache.adopt_pools(&pools), "empty-cache adoption");

                for (step, &(op, l, h, rp)) in ops.iter().enumerate() {
                    let pos = rp % cache.len().max(1);
                    match op {
                        0 => {
                            let want = (cache.len() + 1 + rp % 7).min(t_max);
                            cache.fill(want); // may refuse: that's the point
                        }
                        1 => {
                            cache.evict(l, h, pos);
                        }
                        2 => {
                            let refusals = cache.demote_refusals();
                            let before = cache.stats();
                            if !cache.demote(l, h, pos) && cache.is_kept(l, h, pos) {
                                // pressure refusal: state must be untouched
                                let after = cache.stats();
                                if (after.kept, after.demoted, after.side_bytes)
                                    != (before.kept, before.demoted, before.side_bytes)
                                {
                                    return Err(format!(
                                        "step {step}: refused demote moved tier state"
                                    ));
                                }
                                if cache.demote_refusals() != refusals + 1 {
                                    return Err(format!(
                                        "step {step}: pressure refusal not counted"
                                    ));
                                }
                            }
                        }
                        3 => {
                            cache.rehydrate(l, h, pos);
                        }
                        _ => {
                            cache.drop_demoted(l, h, pos);
                        }
                    }
                    cache.accounting_ok().map_err(|e| format!("step {step}: {e}"))?;
                    let s = cache.stats();
                    if split {
                        if bpool.used() != s.resident_blocks {
                            return Err(format!(
                                "step {step}: block pool used {} != resident {}",
                                bpool.used(),
                                s.resident_blocks
                            ));
                        }
                        if spool.used() != s.side_bytes {
                            return Err(format!(
                                "step {step}: side pool used {} != side bytes {}",
                                spool.used(),
                                s.side_bytes
                            ));
                        }
                        if bpool.used() > blocks || spool.used() > side_entries * bpe {
                            return Err(format!("step {step}: split budget exceeded"));
                        }
                    } else {
                        if upool.used() != cache.charged_bytes() {
                            return Err(format!(
                                "step {step}: unified pool used {} != charged {}",
                                upool.used(),
                                cache.charged_bytes()
                            ));
                        }
                        if cache.charged_bytes() > unified_total {
                            return Err(format!(
                                "step {step}: charged {} exceeds budget {unified_total}",
                                cache.charged_bytes()
                            ));
                        }
                    }
                }
                cache.release();
                let leak = if split {
                    bpool.used() + spool.used()
                } else {
                    upool.used()
                };
                if leak != 0 {
                    return Err(format!("release leaked {leak} pool units (split={split})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tokenizer_roundtrip() {
    check(
        80,
        |r| {
            let n = r.below(100);
            (0..n)
                .map(|_| (r.below(94) + 32) as u8 as char)
                .collect::<String>()
        },
        |s| {
            let t = workload::ByteTokenizer::default();
            let ids = t.encode(s, 512);
            let back = t.decode(&ids[1..]);
            if &back == s {
                Ok(())
            } else {
                Err(format!("{s:?} -> {back:?}"))
            }
        },
    );
}
