//! Engine-level integration tests: runtime bucket resolution, generation
//! end to end over the reference backend, and the step-level session API
//! (Sequence / prefill / decode_step with the device-resident KV cache).
//!
//! Split from the original tests/integration.rs — same tests, same names.

mod common;

use std::sync::Arc;

use common::engine;
use kvzap::coordinator::{Engine, SamplingParams, Sequence};
use kvzap::policies;
use kvzap::runtime::Runtime;
use kvzap::util::rng::Rng;
use kvzap::workload;

// ---------------------------------------------------------------------------
// Runtime-level

#[test]
fn manifest_buckets_resolve() {
    let e = engine();
    assert_eq!(e.rt.backend_name(), "reference");
    let m = &e.rt.manifest;
    assert!(m.prefill_bucket(100, 1).is_some());
    assert!(m.prefill_bucket(m.model.t_max, 4).is_some());
    assert!(m.prefill_bucket(m.model.t_max + 1, 1).is_none());
    assert!(m.decode_bucket(1).is_some());
    assert!(m.kvzip_bucket(200).is_some());
}

#[test]
fn generate_full_cache_is_deterministic() {
    let e = engine();
    let mut rng = Rng::new(1);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let policy = policies::by_name("full", e.window()).unwrap();
    let sp = SamplingParams::greedy(8);
    let a = e.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
    let b = e.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
    assert_eq!(a.text, b.text);
    assert_eq!(a.compression, 0.0, "full cache never compresses");
}

#[test]
fn kvzap_policy_compresses_and_still_generates() {
    let e = engine();
    let mut rng = Rng::new(2);
    let task = workload::ruler_instance("niah_single_1", 220, &mut rng);
    let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
    let r = e
        .generate(&task.prompt, policy.as_ref(), &SamplingParams::greedy(8))
        .unwrap();
    assert!(r.compression > 0.05, "tau=-4 should evict something: {}", r.compression);
    assert!(r.compression < 0.99);
}

#[test]
fn higher_threshold_compresses_more() {
    let e = engine();
    let mut rng = Rng::new(3);
    let task = workload::ruler_instance("niah_multikey_1", 220, &mut rng);
    let sp = SamplingParams::greedy(4);
    let mut last = -1.0;
    for tau in [-8.0f64, -4.0, -1.0] {
        let p = policies::by_name(&format!("kvzap_mlp:{tau}"), e.window()).unwrap();
        let r = e.generate(&task.prompt, p.as_ref(), &sp).unwrap();
        assert!(
            r.compression >= last - 1e-9,
            "compression must be monotone in tau: {} then {}",
            last,
            r.compression
        );
        last = r.compression;
    }
    assert!(last > 0.05, "the aggressive threshold must actually prune");
}

#[test]
fn oracle_policy_runs_double_pass() {
    let e = engine();
    let mut rng = Rng::new(4);
    let task = workload::ruler_instance("niah_single_2", 180, &mut rng);
    let p = policies::by_name("kvzip_plus:0.5", e.window()).unwrap();
    let r = e.generate(&task.prompt, p.as_ref(), &SamplingParams::greedy(4)).unwrap();
    assert!(r.oracle_us > 0, "oracle pass must have run");
    // budget 0.5 with window protection -> roughly half removed
    assert!(r.compression > 0.3 && r.compression < 0.6, "{}", r.compression);
}

#[test]
fn batched_generation_matches_single() {
    let e = engine();
    let mut rng = Rng::new(5);
    let tasks: Vec<_> = (0..3)
        .map(|i| workload::ruler_instance("niah_single_1", 200, &mut rng.fork(i)))
        .collect();
    let p = policies::by_name("full", e.window()).unwrap();
    let sp = SamplingParams::greedy(6);
    let singles: Vec<String> = tasks
        .iter()
        .map(|t| e.generate(&t.prompt, p.as_ref(), &sp).unwrap().text)
        .collect();
    let prompts: Vec<&str> = tasks.iter().map(|t| t.prompt.as_str()).collect();
    let batched = e.generate_batch(&prompts, p.as_ref(), &sp).unwrap();
    for (s, b) in singles.iter().zip(&batched) {
        assert_eq!(s, &b.text, "slot-batched decode must match single decode");
    }
}

#[test]
fn score_answer_full_beats_random_eviction() {
    let e = engine();
    let mut rng = Rng::new(6);
    let task = workload::ruler_instance("niah_single_1", 220, &mut rng);
    let full = policies::by_name("full", e.window()).unwrap();
    let rand = policies::by_name("random:0.15", e.window()).unwrap();
    let (nll_full, c0) = e.score_answer(&task.prompt, &task.answer, full.as_ref()).unwrap();
    let (nll_rand, c1) = e.score_answer(&task.prompt, &task.answer, rand.as_ref()).unwrap();
    assert_eq!(c0, 0.0);
    assert!(c1 > 0.5);
    assert!(
        nll_rand > nll_full,
        "evicting 85% of the cache at random must hurt: full {nll_full} vs random {nll_rand}"
    );
}

#[test]
fn decode_time_eviction_happens_on_long_generation() {
    let e = engine();
    let mut rng = Rng::new(7);
    let a = workload::aime_instance(&mut rng);
    // very aggressive threshold: everything below +inf gets evicted when
    // it leaves the window
    let p = policies::by_name("kvzap_mlp:100", e.window()).unwrap();
    let r = e
        .generate(&a.task.prompt, p.as_ref(), &SamplingParams::greedy(40))
        .unwrap();
    if r.tokens_out > e.window() + 2 {
        assert!(r.decode_evictions > 0, "decode-time evictions expected");
    }
}

/// The paper's core claim, end to end: a KVzap-thresholded generation
/// removes a large fraction of the KV cache while reproducing the
/// full-cache output exactly on a RULER needle-in-a-haystack task.
/// (Reference-weight margins: compression ≈ 0.87, smallest greedy argmax
/// margin along both trajectories ≈ 0.96 logits — see runtime/reference.rs.)
#[test]
fn kvzap_pruned_generation_matches_full_cache_on_ruler_niah() {
    let e = engine();
    let mut rng = Rng::new(99);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let sp = SamplingParams::greedy(8);
    let full = policies::by_name("full", e.window()).unwrap();
    let kvzap = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
    let rf = e.generate(&task.prompt, full.as_ref(), &sp).unwrap();
    let rk = e.generate(&task.prompt, kvzap.as_ref(), &sp).unwrap();
    assert!(!rf.text.is_empty(), "full-cache run must generate tokens");
    assert_eq!(rf.compression, 0.0);
    assert_eq!(
        rf.text, rk.text,
        "KVzap-pruned generation must match the full-cache output"
    );
    assert!(rk.compression > 0.3, "pruning must remove a large fraction: {}", rk.compression);
    assert!(rk.compression < 0.99);
}

// ---------------------------------------------------------------------------
// Tiered demotion (two-threshold policies)

/// Metamorphic pin: `floor = τ` makes the demotion band empty, so the
/// tiered path must be *bitwise* identical to the drop-only policy at the
/// same τ — same tokens, same compression, same cache accounting, same
/// teacher-forced NLL, and zero demotions/rehydrations end to end.
#[test]
fn tiered_floor_equal_tau_is_bitwise_identical_to_drop_only() {
    let e = engine();
    let mut rng = Rng::new(21);
    let task = workload::ruler_instance("niah_single_1", 220, &mut rng);
    let sp = SamplingParams::greedy(10);
    for (drop_spec, tier_spec) in [
        ("kvzap_mlp:-4", "kvzap_mlp:-4:floor=-4"),
        ("fastkvzip:-4", "fastkvzip:-4:floor=-4"),
    ] {
        let drop = policies::by_name(drop_spec, e.window()).unwrap();
        let tier = policies::by_name(tier_spec, e.window()).unwrap();
        let rd = e.generate(&task.prompt, drop.as_ref(), &sp).unwrap();
        let rt = e.generate(&task.prompt, tier.as_ref(), &sp).unwrap();
        assert_eq!(rd.text, rt.text, "{tier_spec}: tokens diverged from {drop_spec}");
        assert_eq!(rd.compression, rt.compression, "{tier_spec}: compression diverged");
        assert_eq!(rd.decode_evictions, rt.decode_evictions, "{tier_spec}");
        assert_eq!(rt.decode_demotions, 0, "{tier_spec}: empty band must never demote");
        assert_eq!(rt.decode_rehydrations, 0, "{tier_spec}");
        let ad = e.score_answer_full(&task.prompt, &task.answer, drop.as_ref()).unwrap();
        let at = e.score_answer_full(&task.prompt, &task.answer, tier.as_ref()).unwrap();
        assert_eq!(ad.nll, at.nll, "{tier_spec}: answer NLL must match bitwise");
        assert_eq!(ad.kv_bytes, at.kv_bytes, "{tier_spec}: same bytes with an empty band");
        assert_eq!(at.demoted, 0, "{tier_spec}");
        assert_eq!(at.rehydrated, 0, "{tier_spec}");
        assert_eq!(at.quant_attended, 0, "{tier_spec}: empty band, nothing to quant-attend");
    }
}

/// A deep floor under an aggressive τ routes window-exiting positions
/// into the quantized side tier instead of dropping them, both at prefill
/// (via `score_answer_full`'s steady state) and during decode — and the
/// side tier prices the band cheaper than keeping it resident: the tiered
/// steady state takes strictly fewer bytes than drop-at-floor while
/// holding strictly more information than drop-at-τ.
#[test]
fn tiered_policy_demotes_into_side_tier_and_undercuts_drop_at_floor() {
    let e = engine();
    let mut rng = Rng::new(22);
    let task = workload::ruler_instance("niah_multikey_1", 220, &mut rng);
    let tiered = policies::by_name("kvzap_mlp:-1:floor=-8", e.window()).unwrap();
    let a_tier = e.score_answer_full(&task.prompt, &task.answer, tiered.as_ref()).unwrap();
    assert!(a_tier.demoted > 0, "the [-8, -1) band must land in the side tier");
    assert_eq!(a_tier.rehydrated, 0, "answer scoring attends the band in place, no rehydrate");
    assert!(a_tier.quant_attended > 0, "demoted rows must be scored from their quantized form");

    // the bytes win, in its purest form: demote *everything* outside the
    // protected window (τ=+∞, bottomless floor) vs keeping everything
    // resident (drop-only at the same bottomless τ). Every fully-banded
    // 16-slot block frees 1024 resident bytes and charges 16 × 32 = 512
    // side bytes, so the tiered footprint must come in strictly under —
    // this is the structural half-price guarantee the leaderboard's
    // dominance report generalizes to mid-τ pairs.
    let band_all = policies::by_name("kvzap_mlp:100:floor=-1e30", e.window()).unwrap();
    let keep_all = policies::by_name("kvzap_mlp:-1e30", e.window()).unwrap();
    let a_band = e.score_answer_full(&task.prompt, &task.answer, band_all.as_ref()).unwrap();
    let a_keep = e.score_answer_full(&task.prompt, &task.answer, keep_all.as_ref()).unwrap();
    assert_eq!(a_keep.demoted, 0, "drop-only never demotes");
    assert!(a_band.demoted > 0, "everything outside the window demotes");
    assert!(
        a_band.kv_bytes < a_keep.kv_bytes,
        "int8 side entries must undercut resident fp32 blocks: tiered {} vs resident {}",
        a_band.kv_bytes,
        a_keep.kv_bytes
    );

    // decode-time: an aggressive τ with a bottomless floor demotes every
    // window-exiting position instead of evicting it
    let all_demote = policies::by_name("kvzap_mlp:100:floor=-1e30", e.window()).unwrap();
    let a = workload::aime_instance(&mut rng);
    let r = e
        .generate(&a.task.prompt, all_demote.as_ref(), &SamplingParams::greedy(40))
        .unwrap();
    if r.tokens_out > e.window() + 2 {
        assert!(r.decode_demotions > 0, "decode-time demotions expected");
        assert_eq!(
            r.decode_evictions, 0,
            "nothing scores below -1e30, so the band absorbs every exit"
        );
    }
}

/// Metamorphic pin for the no-rehydrate re-score path: scoring the answer
/// with the demoted band attended **from its quantized form** must be
/// bitwise identical to rehydrating the band first and attending fp32 —
/// same NLL (the quantization round-trip is deterministic, so the
/// dequantized-in-register rows equal the rehydrated rows), same pruning
/// decisions, same steady-state bytes. Only the side-tier traffic
/// counters may differ: quant-attend never rehydrates.
#[test]
fn quant_rescore_is_bitwise_identical_to_rehydrate_rescore() {
    use kvzap::coordinator::RescoreMode;
    let e = engine();
    let mut rng = Rng::new(47);
    for (name, tlen) in [("niah_multikey_1", 220), ("niah_single_2", 180)] {
        let task = workload::ruler_instance(name, tlen, &mut rng);
        let tiered = policies::by_name("kvzap_mlp:-1:floor=-8", e.window()).unwrap();
        let q = e
            .score_answer_mode(&task.prompt, &task.answer, tiered.as_ref(), RescoreMode::QuantAttend)
            .unwrap();
        let r = e
            .score_answer_mode(&task.prompt, &task.answer, tiered.as_ref(), RescoreMode::Rehydrate)
            .unwrap();
        assert!(q.demoted > 0, "{name}: the band must be non-empty for this pin to bite");
        assert_eq!(q.demoted, r.demoted, "{name}: identical prefill pruning decisions");
        assert_eq!(q.compression, r.compression, "{name}");
        assert_eq!(q.kv_bytes, r.kv_bytes, "{name}: steady-state bytes priced identically");
        assert_eq!(
            q.nll.to_bits(),
            r.nll.to_bits(),
            "{name}: quant-attend NLL must match rehydrate-then-score bitwise"
        );
        // the two modes differ only in how the band reaches the attention op
        assert_eq!(q.rehydrated, 0, "{name}");
        assert!(q.quant_attended > 0, "{name}");
        assert_eq!(r.rehydrated, r.demoted, "{name}");
        assert_eq!(r.quant_attended, 0, "{name}");
    }
}

// ---------------------------------------------------------------------------
// Step-level session API (Sequence / prefill / decode_step)

/// A sequence that joins a running decode group mid-flight must produce
/// exactly the tokens it would produce alone — the per-slot decode is
/// independent, which is what makes continuous batching sound.
#[test]
fn sequence_joining_mid_decode_matches_single() {
    let e = engine();
    let mut rng = Rng::new(33);
    let t1 = workload::ruler_instance("niah_single_1", 200, &mut rng.fork(1));
    let t2 = workload::ruler_instance("niah_single_2", 180, &mut rng.fork(2));
    let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
    let sp = SamplingParams::greedy(8);
    let r1 = e.generate(&t1.prompt, policy.as_ref(), &sp).unwrap();
    let r2 = e.generate(&t2.prompt, policy.as_ref(), &sp).unwrap();

    // session API: s1 decodes alone for three steps, then s2 joins — the
    // persistent DecodeGroup reallocates when the bucket grows and s1's
    // resident rows survive the re-scatter
    let mut group = e.decode_group();
    let mut s1 = e.sequence(1, &t1.prompt, sp.clone());
    e.prefill(&mut s1, policy.as_ref()).unwrap();
    for _ in 0..3 {
        let mut set = vec![&mut s1];
        e.decode_step(&mut group, &mut set).unwrap();
    }
    let mut s2 = e.sequence(2, &t2.prompt, sp.clone());
    e.prefill(&mut s2, policy.as_ref()).unwrap();
    while !s1.is_done() || !s2.is_done() {
        let mut set: Vec<&mut Sequence> = vec![];
        if !s1.is_done() {
            set.push(&mut s1);
        }
        if !s2.is_done() {
            set.push(&mut s2);
        }
        e.decode_step(&mut group, &mut set).unwrap();
    }
    assert_eq!(e.finish(&s1).text, r1.text, "joined sequence must match single decode");
    assert_eq!(e.finish(&s2).text, r2.text, "late joiner must match single decode");
}

/// Device-resident KV cache accounting: with a no-eviction policy, a
/// steady-state decode step transfers only the decoded `[L, H, d_head]`
/// row per sequence — zero KV uploads and zero mask updates after the
/// join. (Uses a private engine so other tests' traffic cannot leak into
/// the counters.)
#[test]
fn resident_decode_transfers_only_the_decoded_row() {
    let e = Engine::new(Arc::new(Runtime::reference()));
    let mut rng = Rng::new(77);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let policy = policies::by_name("full", e.window()).unwrap();
    let mut sp = SamplingParams::greedy(40);
    sp.stop_at_newline = false;
    let mut s = e.sequence(1, &task.prompt, sp);
    e.prefill(&mut s, policy.as_ref()).unwrap();

    let mut group = e.decode_group();
    let mut set = vec![&mut s];
    e.decode_step(&mut group, &mut set).unwrap();
    let m = &e.rt.manifest.model;
    let row_bytes = 4 * 2 * (m.n_layers * m.n_kv_heads * m.d_head) as u64;
    let slot_bytes = 4 * 2 * (m.n_layers * m.n_kv_heads * m.t_max * m.d_head) as u64;
    let after_join = e.rt.transfer.snapshot();
    assert_eq!(after_join.mask_uploads, 1, "the join installs the mask exactly once");
    assert_eq!(
        after_join.kv_bytes_up,
        slot_bytes + 4 * (m.n_layers * m.n_kv_heads * m.t_max) as u64,
        "the join scatters the full slot plus its mask"
    );
    assert_eq!(after_join.kv_bytes_down, row_bytes, "the join step fetches one row");

    let mut steps = 0u64;
    for _ in 0..10 {
        if s.is_done() {
            break;
        }
        let mut set = vec![&mut s];
        e.decode_step(&mut group, &mut set).unwrap();
        steps += 1;
    }
    assert!(steps >= 4, "expected several live steady-state steps, got {steps}");
    let now = e.rt.transfer.snapshot();
    assert_eq!(
        now.mask_uploads, after_join.mask_uploads,
        "a no-eviction policy performs zero mask uploads after prefill/join"
    );
    assert_eq!(
        now.kv_bytes_up, after_join.kv_bytes_up,
        "steady-state decode uploads zero KV bytes"
    );
    assert_eq!(
        now.kv_bytes_down - after_join.kv_bytes_down,
        steps * row_bytes,
        "each step transfers exactly the decoded row per sequence"
    );
    assert_eq!(now.decode_steps, steps + 1);
}

/// An evicting policy refreshes a slot's mask exactly when the previous
/// step's evictions dirtied it (dirty-flag threading) — the upload count
/// is predicted exactly by replaying the protocol against the observed
/// per-step evictions.
#[test]
fn resident_decode_mask_refreshes_track_evictions() {
    let e = Engine::new(Arc::new(Runtime::reference()));
    let mut rng = Rng::new(78);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    // tau=100 evicts every token the moment it leaves the decode window
    let policy = policies::by_name("kvzap_mlp:100", e.window()).unwrap();
    let mut sp = SamplingParams::greedy(60);
    sp.stop_at_newline = false;
    let mut s = e.sequence(1, &task.prompt, sp);
    e.prefill(&mut s, policy.as_ref()).unwrap();
    let mut group = e.decode_group();
    let mut expected_uploads = 0u64;
    let mut pending_dirty = true; // prefill pruning dirtied the mask
    let mut total_evicted = 0usize;
    let mut joined = false;
    for _ in 0..(e.window() + 8) {
        if s.is_done() {
            break;
        }
        // protocol replay: the join installs the mask (consuming any
        // pending dirt); afterwards a refresh happens at the start of a
        // step iff the previous step evicted
        if !joined || pending_dirty {
            expected_uploads += 1;
        }
        joined = true;
        pending_dirty = false;
        let before = s.decode_evictions;
        let mut set = vec![&mut s];
        e.decode_step(&mut group, &mut set).unwrap();
        if s.decode_evictions > before {
            pending_dirty = true;
            total_evicted += s.decode_evictions - before;
        }
    }
    assert!(total_evicted > 0, "the aggressive threshold must evict during decode");
    let snap = e.rt.transfer.snapshot();
    assert_eq!(
        snap.mask_uploads, expected_uploads,
        "mask uploads must be driven by the dirty flag, not by step count"
    );
}

/// Join/leave/rejoin equivalence on the resident-cache path: a sequence
/// that joins a running group mid-decode, leaves for a few steps and
/// rejoins must produce bit-identical text and CacheStats to the same
/// sequence decoded solo (extends the PR 2 mid-decode join test).
#[test]
fn sequence_leaving_and_rejoining_matches_solo() {
    let e = engine();
    let mut rng = Rng::new(55);
    let t1 = workload::ruler_instance("niah_single_1", 200, &mut rng.fork(1));
    let t2 = workload::ruler_instance("niah_single_2", 180, &mut rng.fork(2));
    let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
    let mut sp = SamplingParams::greedy(12);
    sp.stop_at_newline = false;

    // solo references via the same session API
    let solo = |prompt: &str, id: u64| {
        let mut g = e.decode_group();
        let mut s = e.sequence(id, prompt, sp.clone());
        e.prefill(&mut s, policy.as_ref()).unwrap();
        while !s.is_done() {
            let mut set = vec![&mut s];
            e.decode_step(&mut g, &mut set).unwrap();
        }
        (e.finish(&s).text, s.cache_stats())
    };
    let (text1, stats1) = solo(&t1.prompt, 91);
    let (text2, stats2) = solo(&t2.prompt, 92);

    // interleaved run: s1+s2 together, s1 leaves, s2 alone (bucket shrinks
    // to b1 — full realloc), s1 rejoins (bucket grows back)
    let mut group = e.decode_group();
    let mut s1 = e.sequence(1, &t1.prompt, sp.clone());
    let mut s2 = e.sequence(2, &t2.prompt, sp.clone());
    e.prefill(&mut s1, policy.as_ref()).unwrap();
    e.prefill(&mut s2, policy.as_ref()).unwrap();
    for _ in 0..2 {
        let mut set: Vec<&mut Sequence> = vec![];
        if !s1.is_done() {
            set.push(&mut s1);
        }
        if !s2.is_done() {
            set.push(&mut s2);
        }
        if set.is_empty() {
            break;
        }
        e.decode_step(&mut group, &mut set).unwrap();
    }
    for _ in 0..3 {
        if s2.is_done() {
            break;
        }
        let mut set = vec![&mut s2];
        e.decode_step(&mut group, &mut set).unwrap();
    }
    while !s1.is_done() || !s2.is_done() {
        let mut set: Vec<&mut Sequence> = vec![];
        if !s1.is_done() {
            set.push(&mut s1);
        }
        if !s2.is_done() {
            set.push(&mut s2);
        }
        e.decode_step(&mut group, &mut set).unwrap();
    }
    assert_eq!(e.finish(&s1).text, text1, "leave/rejoin must not change s1's tokens");
    assert_eq!(e.finish(&s2).text, text2, "shrink/grow reallocs must not change s2's tokens");
    assert_eq!(s1.cache_stats(), stats1, "s1 CacheStats must match the solo run");
    assert_eq!(s2.cache_stats(), stats2, "s2 CacheStats must match the solo run");
}

/// Prefix snapshots × the demoted tier (extends the rejoin round-trip
/// test above): a snapshot captured from a tiered (`:floor=`) prefill
/// carries the quantized side pool, and a fresh sequence resumed from it
/// must re-demote bitwise on its group join — identical text and
/// identical CacheStats (kept / demoted / side-tier bytes) to the donor
/// run — across every tier code width.
#[test]
fn tiered_prefill_snapshot_resumes_bitwise_across_code_widths() {
    let e = engine();
    let mut rng = Rng::new(56);
    let task = workload::ruler_instance("niah_single_1", 220, &mut rng);
    let mut sp = SamplingParams::greedy(10);
    sp.stop_at_newline = false;
    for bits in [8usize, 4, 2] {
        let spec = format!("kvzap_mlp:-1:floor=-8:bits={bits}");
        let policy = policies::by_name(&spec, e.window()).unwrap();

        // donor: fresh tiered prefill, snapshot taken before the first
        // token sample (what the prefix cache stores on a miss)
        let mut donor = e.sequence(60 + bits as u64, &task.prompt, sp.clone());
        let (_, snap) = e.prefill_with_snapshot(&mut donor, policy.as_ref()).unwrap();
        assert_eq!(snap.prompt_len(), task.prompt.len() + 1, "byte tokens + BOS");
        assert!(snap.approx_bytes() > 0);
        let mut g = e.decode_group();
        while !donor.is_done() {
            let mut set = vec![&mut donor];
            e.decode_step(&mut g, &mut set).unwrap();
        }
        let donor_text = e.finish(&donor).text;
        let donor_stats = donor.cache_stats();
        assert!(
            donor_stats.demoted > 0,
            "bits={bits}: the floor band must demote during prefill for this test to bite"
        );
        assert!(donor_stats.side_bytes > 0, "bits={bits}: demoted rows occupy side bytes");

        // resumed: a fresh sequence installs the snapshot (a cache hit)
        // instead of running the prefill bucket, then decodes solo
        let mut resumed = e.sequence(70 + bits as u64, &task.prompt, sp.clone());
        e.prefill_from_snapshot(&mut resumed, &snap).unwrap();
        let mut g2 = e.decode_group();
        while !resumed.is_done() {
            let mut set = vec![&mut resumed];
            e.decode_step(&mut g2, &mut set).unwrap();
        }
        assert_eq!(
            e.finish(&resumed).text,
            donor_text,
            "bits={bits}: snapshot resume changed the token stream"
        );
        assert_eq!(
            resumed.cache_stats(),
            donor_stats,
            "bits={bits}: snapshot resume changed the cache accounting"
        );
    }
}
