//! Simulation-harness tests: clean seeded runs, bitwise reproducibility,
//! thread-count invariance, the scenario JSON round-trip, and the mutation
//! self-check (a deliberately injected accounting bug must be caught by
//! the invariant registry and minimized to a replayable scenario).

use kvzap::policies::PolicySpec;
use kvzap::simharness::{
    reuse_traces_match, run_scenario, shard_traces_match, simulate, thread_traces_match,
    ClientScript, Fault, ScenarioSpec, SimOptions,
};
use kvzap::util::json::Json;
use kvzap::util::rng::Rng;
use kvzap::workload;

/// Seeded scenarios run clean: every per-step invariant holds and every
/// client's interleaved stream matches its solo replay.
#[test]
fn simulate_small_scenarios_run_clean() {
    for seed in 0..3u64 {
        let spec = ScenarioSpec::generate(seed, 36, 4, 3);
        let report = run_scenario(&spec, &SimOptions::default());
        assert!(
            report.violation.is_none(),
            "seed {seed}: {}",
            report.violation.unwrap()
        );
        assert_eq!(report.steps_run, 36);
    }
}

/// The same spec and options produce the same trace, bit for bit
/// (tokens, reasons, compression bits, transfer counters).
#[test]
fn simulate_is_bitwise_reproducible() {
    let spec = ScenarioSpec::generate(7, 30, 4, 4);
    let opts = SimOptions { check_solo: false, ..SimOptions::default() };
    let a = run_scenario(&spec, &opts);
    let b = run_scenario(&spec, &opts);
    assert!(a.violation.is_none(), "{}", a.violation.unwrap());
    assert!(b.violation.is_none(), "{}", b.violation.unwrap());
    assert_eq!(a.trace, b.trace, "fixed seed + fixed threads must be bitwise reproducible");
}

/// Replaying a scenario at KVZAP_THREADS=1 vs 2 yields identical traces —
/// the determinism rule every backend must satisfy (docs/TESTING.md).
#[test]
fn simulate_thread_count_invariant() {
    let spec = ScenarioSpec::generate(11, 28, 3, 4);
    thread_traces_match(&spec, 1, 2).unwrap();
}

/// ScenarioSpec round-trips through its JSON form (what --spec-file
/// replays after a shrink).
#[test]
fn scenario_spec_json_roundtrip() {
    let spec = ScenarioSpec::generate(3, 40, 5, 4);
    let dumped = spec.to_json().dump();
    let parsed = ScenarioSpec::from_json(&Json::parse(&dumped).unwrap()).unwrap();
    assert_eq!(spec, parsed);
}

/// Mutation self-check: inject a phantom KV transfer mid-run and require
/// the transfer-accounting invariant to fire, produce a replay line, and
/// minimize to a still-failing, still-replayable scenario.
#[test]
fn injected_accounting_bug_is_caught_and_minimized() {
    let mut rng = Rng::new(77);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let client = ClientScript {
        join_step: 0,
        tenant: String::new(),
        prompt: task.prompt,
        policy: PolicySpec::Full,
        structured_policy: false,
        max_new: 24,
        greedy: true,
        seed: 1,
        stop_newline: false,
        cancel_step: None,
        drop_step: None,
    };
    let spec = ScenarioSpec { seed: 0, steps: 20, max_batch: 2, clients: vec![client] };
    let opts = SimOptions {
        check_solo: false,
        fault: Some(Fault::PhantomRowFetch { step: 5 }),
        ..SimOptions::default()
    };

    // sanity: without the fault the scenario is clean
    let clean = run_scenario(&spec, &SimOptions { fault: None, ..opts.clone() });
    assert!(clean.violation.is_none(), "{}", clean.violation.unwrap());

    let failure = simulate(&spec, &opts).expect_err("the injected bug must be caught");
    assert_eq!(
        failure.violation.invariant, "transfer-accounting",
        "unexpected invariant: {}",
        failure.violation
    );
    assert_eq!(failure.violation.step, 5, "caught at the injection step");
    assert!(failure.replay.starts_with("kvzap simulate --seed"), "{}", failure.replay);
    assert!(
        failure.replay.contains("--fault-step 5") && failure.replay.contains("--no-solo"),
        "the replay line must carry the run options: {}",
        failure.replay
    );

    // the minimized scenario replays from its JSON and still fails
    let parsed =
        ScenarioSpec::from_json(&Json::parse(&failure.minimized_json).unwrap()).unwrap();
    assert_eq!(parsed, failure.minimized);
    let replayed = run_scenario(&parsed, &opts);
    let v = replayed.violation.expect("minimized scenario must still fail");
    assert_eq!(v.invariant, "transfer-accounting");
}

/// Mutation self-check for the quantized decode path: a backend that
/// reports quant-attended rows it never served (a rogue counter bump with
/// no matching demoted entries) must trip the transfer-accounting
/// invariant's quant fields at exactly the injection step.
#[test]
fn injected_phantom_quant_attend_is_caught() {
    let mut rng = Rng::new(78);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let client = ClientScript {
        join_step: 0,
        tenant: String::new(),
        prompt: task.prompt,
        policy: PolicySpec::Full,
        structured_policy: false,
        max_new: 16,
        greedy: true,
        seed: 1,
        stop_newline: false,
        cancel_step: None,
        drop_step: None,
    };
    let spec = ScenarioSpec { seed: 0, steps: 12, max_batch: 2, clients: vec![client] };
    let opts = SimOptions {
        check_solo: false,
        fault: Some(Fault::PhantomQuantAttend { step: 4 }),
        ..SimOptions::default()
    };

    // sanity: without the fault the scenario is clean
    let clean = run_scenario(&spec, &SimOptions { fault: None, ..opts.clone() });
    assert!(clean.violation.is_none(), "{}", clean.violation.unwrap());

    let failure = simulate(&spec, &opts).expect_err("the phantom quant attend must be caught");
    assert_eq!(
        failure.violation.invariant, "transfer-accounting",
        "unexpected invariant: {}",
        failure.violation
    );
    assert_eq!(failure.violation.step, 4, "caught at the injection step");
    assert!(
        failure.replay.contains("--fault-quant-step 4"),
        "the replay line must carry the quant fault flag: {}",
        failure.replay
    );
}

/// Demotion-heavy scripted episodes (tiered two-threshold policies only)
/// run clean under the full registry — tier conservation, the window
/// re-entry backstop, accounting balance, transfer prediction, and the
/// solo-replay faithfulness check all hold through demote/rehydrate churn.
#[test]
fn simulate_tiered_scenarios_run_clean() {
    for seed in 0..2u64 {
        let spec = ScenarioSpec::generate_tiered(seed, 32, 3, 3);
        assert!(
            spec.clients.iter().all(|c| {
                matches!(
                    &c.policy,
                    PolicySpec::Kvzap { floor: Some(_), .. }
                        | PolicySpec::FastKvzip { floor: Some(_), .. }
                )
            }),
            "tiered episodes script two-threshold policies exclusively"
        );
        let report = run_scenario(&spec, &SimOptions::default());
        assert!(
            report.violation.is_none(),
            "seed {seed}: {}",
            report.violation.unwrap()
        );
        assert_eq!(report.steps_run, 32);
    }
}

/// Mutation self-check for the router layer's prefix accounting: a
/// scheduler whose hit counter runs ahead of the snapshot installs it
/// claims must trip the prefix-accounting check at exactly the injection
/// step, and shrink to a replayable one-liner carrying the fault flag.
#[test]
fn injected_phantom_prefix_hit_is_caught() {
    let mut rng = Rng::new(79);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let client = ClientScript {
        join_step: 0,
        tenant: "acme".into(),
        prompt: task.prompt,
        policy: PolicySpec::Full,
        structured_policy: false,
        max_new: 16,
        greedy: true,
        seed: 1,
        stop_newline: false,
        cancel_step: None,
        drop_step: None,
    };
    let spec = ScenarioSpec { seed: 0, steps: 12, max_batch: 2, clients: vec![client] };
    let opts = SimOptions {
        check_solo: false,
        prefix_reuse: true, // the pool path, with the reuse machinery live
        fault: Some(Fault::PhantomPrefixHit { step: 3 }),
        ..SimOptions::default()
    };

    // sanity: without the fault the scenario is clean
    let clean = run_scenario(&spec, &SimOptions { fault: None, ..opts.clone() });
    assert!(clean.violation.is_none(), "{}", clean.violation.unwrap());

    let failure = simulate(&spec, &opts).expect_err("the phantom hit must be caught");
    assert_eq!(
        failure.violation.invariant, "prefix-accounting",
        "unexpected invariant: {}",
        failure.violation
    );
    assert_eq!(failure.violation.step, 3, "caught at the injection step");
    assert!(
        failure.replay.contains("--fault-prefix-step 3")
            && failure.replay.contains("--prefix-reuse")
            && failure.replay.contains("--no-solo"),
        "the replay line must carry the run options: {}",
        failure.replay
    );

    // the minimized scenario replays from its JSON and still fails
    let parsed =
        ScenarioSpec::from_json(&Json::parse(&failure.minimized_json).unwrap()).unwrap();
    assert_eq!(parsed, failure.minimized);
    let replayed = run_scenario(&parsed, &opts);
    let v = replayed.violation.expect("minimized scenario must still fail");
    assert_eq!(v.invariant, "prefix-accounting");
}

/// Mutation self-check for the router: a placement that silently moves
/// without a recorded rebalance must trip the placement-stability check
/// at exactly the injection step.
#[test]
fn injected_phantom_misroute_is_caught() {
    let mut rng = Rng::new(80);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let client = ClientScript {
        join_step: 0,
        tenant: "acme".into(),
        prompt: task.prompt,
        policy: PolicySpec::Full,
        structured_policy: false,
        max_new: 16,
        greedy: true,
        seed: 1,
        stop_newline: false,
        cancel_step: None,
        drop_step: None,
    };
    let spec = ScenarioSpec { seed: 0, steps: 12, max_batch: 2, clients: vec![client] };
    let opts = SimOptions {
        check_solo: false,
        shards: 2, // a silent move is a no-op at one shard
        fault: Some(Fault::PhantomMisroute { step: 4 }),
        ..SimOptions::default()
    };

    // sanity: without the fault the sharded scenario is clean
    let clean = run_scenario(&spec, &SimOptions { fault: None, ..opts.clone() });
    assert!(clean.violation.is_none(), "{}", clean.violation.unwrap());

    let failure = simulate(&spec, &opts).expect_err("the silent move must be caught");
    assert_eq!(
        failure.violation.invariant, "placement-stability",
        "unexpected invariant: {}",
        failure.violation
    );
    assert_eq!(failure.violation.step, 4, "caught at the injection step");
    assert!(
        failure.replay.contains("--shards 2")
            && failure.replay.contains("--fault-route-step 4"),
        "the replay line must carry the shard count and fault flag: {}",
        failure.replay
    );
}

/// Metamorphic shard invariance (the headline router claim): a fixed
/// seeded shared-prefix episode produces bit-identical per-request
/// outputs at 1 shard and at 4 shards.
#[test]
fn shard_count_is_output_invariant_on_shared_prefix_episodes() {
    for seed in 0..2u64 {
        let spec = ScenarioSpec::generate_shared_prefix(seed, 96, 4, 3);
        shard_traces_match(&spec, 1, 4).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

/// Metamorphic prefix-reuse invariance: with the cross-request prefix
/// cache on, outputs are bit-identical to the reuse-off run — and the
/// helper itself rejects a run that never hit the cache, so this also
/// pins that shared-prefix episodes really exercise reuse.
#[test]
fn prefix_reuse_is_output_invariant_and_actually_hits() {
    let spec = ScenarioSpec::generate_shared_prefix(1, 96, 4, 3);
    reuse_traces_match(&spec, 2).unwrap();
}

/// The clean-run summary counts what the trace shows.
#[test]
fn simulate_summary_counts_clients() {
    let spec = ScenarioSpec::generate(5, 30, 3, 4);
    let opts = SimOptions { check_solo: false, ..SimOptions::default() };
    let summary = simulate(&spec, &opts).expect("seed 5 runs clean");
    assert_eq!(summary.clients, 3);
    assert_eq!(summary.seed, 5);
    assert!(summary.completed + summary.cancelled <= summary.clients);
}

/// Memory-governance acceptance, unified pool: a probe run with an
/// effectively unlimited budget records the workload's charged-bytes
/// high-water mark; rerunning one byte below it turns the peak-setting
/// allocation into a graceful demotion refusal (the caller drops the
/// entry instead), while the pool-budget invariant — charged ≤ budget,
/// no over-release, counter equals the live-sequence recount — holds at
/// every step of both runs.
#[test]
fn unified_pool_budget_holds_and_pressure_forces_demote_refusals() {
    use kvzap::policies::Surrogate;
    use kvzap::runtime::kernels::QuantBits;

    let mut rng = Rng::new(81);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let client = ClientScript {
        join_step: 0,
        tenant: String::new(),
        prompt: task.prompt,
        // τ far above every score with a floor far below any: prefill
        // demotes every prunable position, marching side bytes up against
        // the unified pool while resident blocks vacate under it
        policy: PolicySpec::Kvzap {
            surrogate: Surrogate::Mlp,
            tau: 100.0,
            floor: Some(-100000.0),
            bits: QuantBits::Int8,
        },
        structured_policy: false,
        max_new: 48,
        greedy: true,
        seed: 1,
        stop_newline: false,
        cancel_step: None,
        drop_step: None,
    };
    let spec = ScenarioSpec { seed: 0, steps: 20, max_batch: 2, clients: vec![client] };

    let probe_opts = SimOptions {
        check_solo: false, // solo replays would contend for the charged pool
        kv_budget: Some(1 << 30),
        ..SimOptions::default()
    };
    let probe = run_scenario(&spec, &probe_opts);
    assert!(probe.violation.is_none(), "probe: {}", probe.violation.unwrap());
    assert_eq!(probe.demote_refusals, 0, "a 1 GiB budget must never refuse");
    let peak = probe.kv_pool_peak as usize;
    assert!(peak > 0, "the probe run must charge the pool");

    let bound_opts = SimOptions {
        check_solo: false,
        kv_budget: Some(peak - 1),
        ..SimOptions::default()
    };
    let bound = run_scenario(&spec, &bound_opts);
    assert!(bound.violation.is_none(), "bounded: {}", bound.violation.unwrap());
    assert!(
        bound.demote_refusals >= 1,
        "a budget below the probed peak must refuse at least one demotion"
    );
    assert!(
        (bound.kv_pool_peak as usize) < peak,
        "the bounded run's peak ({}) must stay under the probed one ({peak})",
        bound.kv_pool_peak
    );
}

/// Memory-governance acceptance, split side pool: a side-tier budget too
/// small for even one quantized entry turns every demotion attempt of a
/// demotion-heavy episode into a graceful refusal (drop fallback), with
/// the full registry plus the pool-budget invariant still clean and the
/// pool never admitting a byte.
#[test]
fn tiny_side_budget_refuses_demotions_gracefully() {
    let spec = ScenarioSpec::generate_tiered(0, 32, 3, 3);
    let opts = SimOptions {
        check_solo: false,
        side_budget: Some(1), // below bytes_per_entry at every code width
        ..SimOptions::default()
    };
    let report = run_scenario(&spec, &opts);
    assert!(report.violation.is_none(), "{}", report.violation.unwrap());
    assert_eq!(report.steps_run, 32);
    assert!(
        report.demote_refusals >= 1,
        "a 1-byte side pool must refuse the episode's demotions"
    );
    assert_eq!(report.kv_pool_peak, 0, "nothing is ever admitted to the side pool");
}

/// Memory-governance acceptance, prefix cache: a probe run with no
/// budget records the footprint of the episode's distinct prefill
/// snapshots; rerunning one byte below it forces LRU eviction at the
/// last distinct insert (each snapshot alone still fits, so none are
/// rejected outright), with the run otherwise clean under the relaxed
/// one-sided hit accounting and the final footprint inside the budget.
#[test]
fn bounded_prefix_cache_evicts_under_pressure_and_stays_within_budget() {
    let spec = ScenarioSpec::generate_shared_prefix(2, 64, 6, 3);
    let base = SimOptions {
        check_solo: false,
        prefix_reuse: true,
        ..SimOptions::default()
    };

    let probe = run_scenario(&spec, &base);
    assert!(probe.violation.is_none(), "probe: {}", probe.violation.unwrap());
    assert!(probe.prefix_bytes > 0, "shared-prefix episodes must deposit snapshots");
    assert_eq!(probe.prefix_evictions, 0, "an unbounded cache never evicts");

    // one byte below the combined footprint: with several families, every
    // individual snapshot is at least a byte smaller than the budget, so
    // the last distinct insert must evict rather than be refused
    let budget = (probe.prefix_bytes as usize).saturating_sub(1).max(1);
    let bound =
        run_scenario(&spec, &SimOptions { prefix_budget: Some(budget), ..base });
    assert!(bound.violation.is_none(), "bounded: {}", bound.violation.unwrap());
    assert!(
        bound.prefix_evictions >= 1,
        "a budget under the combined snapshot footprint must evict"
    );
    assert!(
        bound.prefix_bytes as usize <= budget,
        "held bytes ({}) must end inside the budget ({budget})",
        bound.prefix_bytes
    );
}
