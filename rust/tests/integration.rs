//! Integration tests over the full rust stack (runtime + coordinator +
//! policies + server).
//!
//! These run hermetically against the pure-Rust reference backend
//! ([`kvzap::runtime::reference`]) — no `make artifacts`, no python, no
//! skipping. The reference weight set is deterministic and was tuned so
//! every threshold below has a wide margin (see the module docs in
//! runtime/reference.rs); when a PJRT build wants the same coverage over
//! real artifacts it can swap `Runtime::reference()` for `Runtime::auto()`.

use std::sync::Arc;

use kvzap::coordinator::{
    Batcher, BatcherConfig, Engine, Request, Response, SamplingParams, SeqEvent, Sequence,
};
use kvzap::kvcache::{BlockPool, PagedKvCache};
use kvzap::policies::{self, PolicySpec, PrefillView, PrunePolicy, ScoreBuffer};
use kvzap::runtime::{Runtime, Tensor};
use kvzap::util::propcheck::{check, check_with, shrink_vec, Config};
use kvzap::util::rng::Rng;
use kvzap::workload;

/// Shared engine over the hermetic reference backend — always available.
fn engine() -> Arc<Engine> {
    static ENGINE: once_cell::sync::OnceCell<Arc<Engine>> = once_cell::sync::OnceCell::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new(Arc::new(Runtime::reference()))))
        .clone()
}

// ---------------------------------------------------------------------------
// Runtime-level

#[test]
fn manifest_buckets_resolve() {
    let e = engine();
    assert_eq!(e.rt.backend_name(), "reference");
    let m = &e.rt.manifest;
    assert!(m.prefill_bucket(100, 1).is_some());
    assert!(m.prefill_bucket(m.model.t_max, 4).is_some());
    assert!(m.prefill_bucket(m.model.t_max + 1, 1).is_none());
    assert!(m.decode_bucket(1).is_some());
    assert!(m.kvzip_bucket(200).is_some());
}

#[test]
fn generate_full_cache_is_deterministic() {
    let e = engine();
    let mut rng = Rng::new(1);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let policy = policies::by_name("full", e.window()).unwrap();
    let sp = SamplingParams::greedy(8);
    let a = e.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
    let b = e.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
    assert_eq!(a.text, b.text);
    assert_eq!(a.compression, 0.0, "full cache never compresses");
}

#[test]
fn kvzap_policy_compresses_and_still_generates() {
    let e = engine();
    let mut rng = Rng::new(2);
    let task = workload::ruler_instance("niah_single_1", 220, &mut rng);
    let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
    let r = e
        .generate(&task.prompt, policy.as_ref(), &SamplingParams::greedy(8))
        .unwrap();
    assert!(r.compression > 0.05, "tau=-4 should evict something: {}", r.compression);
    assert!(r.compression < 0.99);
}

#[test]
fn higher_threshold_compresses_more() {
    let e = engine();
    let mut rng = Rng::new(3);
    let task = workload::ruler_instance("niah_multikey_1", 220, &mut rng);
    let sp = SamplingParams::greedy(4);
    let mut last = -1.0;
    for tau in [-8.0f64, -4.0, -1.0] {
        let p = policies::by_name(&format!("kvzap_mlp:{tau}"), e.window()).unwrap();
        let r = e.generate(&task.prompt, p.as_ref(), &sp).unwrap();
        assert!(
            r.compression >= last - 1e-9,
            "compression must be monotone in tau: {} then {}",
            last,
            r.compression
        );
        last = r.compression;
    }
    assert!(last > 0.05, "the aggressive threshold must actually prune");
}

#[test]
fn oracle_policy_runs_double_pass() {
    let e = engine();
    let mut rng = Rng::new(4);
    let task = workload::ruler_instance("niah_single_2", 180, &mut rng);
    let p = policies::by_name("kvzip_plus:0.5", e.window()).unwrap();
    let r = e.generate(&task.prompt, p.as_ref(), &SamplingParams::greedy(4)).unwrap();
    assert!(r.oracle_us > 0, "oracle pass must have run");
    // budget 0.5 with window protection -> roughly half removed
    assert!(r.compression > 0.3 && r.compression < 0.6, "{}", r.compression);
}

#[test]
fn batched_generation_matches_single() {
    let e = engine();
    let mut rng = Rng::new(5);
    let tasks: Vec<_> = (0..3)
        .map(|i| workload::ruler_instance("niah_single_1", 200, &mut rng.fork(i)))
        .collect();
    let p = policies::by_name("full", e.window()).unwrap();
    let sp = SamplingParams::greedy(6);
    let singles: Vec<String> = tasks
        .iter()
        .map(|t| e.generate(&t.prompt, p.as_ref(), &sp).unwrap().text)
        .collect();
    let prompts: Vec<&str> = tasks.iter().map(|t| t.prompt.as_str()).collect();
    let batched = e.generate_batch(&prompts, p.as_ref(), &sp).unwrap();
    for (s, b) in singles.iter().zip(&batched) {
        assert_eq!(s, &b.text, "slot-batched decode must match single decode");
    }
}

#[test]
fn score_answer_full_beats_random_eviction() {
    let e = engine();
    let mut rng = Rng::new(6);
    let task = workload::ruler_instance("niah_single_1", 220, &mut rng);
    let full = policies::by_name("full", e.window()).unwrap();
    let rand = policies::by_name("random:0.15", e.window()).unwrap();
    let (nll_full, c0) = e.score_answer(&task.prompt, &task.answer, full.as_ref()).unwrap();
    let (nll_rand, c1) = e.score_answer(&task.prompt, &task.answer, rand.as_ref()).unwrap();
    assert_eq!(c0, 0.0);
    assert!(c1 > 0.5);
    assert!(
        nll_rand > nll_full,
        "evicting 85% of the cache at random must hurt: full {nll_full} vs random {nll_rand}"
    );
}

#[test]
fn decode_time_eviction_happens_on_long_generation() {
    let e = engine();
    let mut rng = Rng::new(7);
    let a = workload::aime_instance(&mut rng);
    // very aggressive threshold: everything below +inf gets evicted when
    // it leaves the window
    let p = policies::by_name("kvzap_mlp:100", e.window()).unwrap();
    let r = e
        .generate(&a.task.prompt, p.as_ref(), &SamplingParams::greedy(40))
        .unwrap();
    if r.tokens_out > e.window() + 2 {
        assert!(r.decode_evictions > 0, "decode-time evictions expected");
    }
}

/// The paper's core claim, end to end: a KVzap-thresholded generation
/// removes a large fraction of the KV cache while reproducing the
/// full-cache output exactly on a RULER needle-in-a-haystack task.
/// (Reference-weight margins: compression ≈ 0.87, smallest greedy argmax
/// margin along both trajectories ≈ 0.96 logits — see runtime/reference.rs.)
#[test]
fn kvzap_pruned_generation_matches_full_cache_on_ruler_niah() {
    let e = engine();
    let mut rng = Rng::new(99);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let sp = SamplingParams::greedy(8);
    let full = policies::by_name("full", e.window()).unwrap();
    let kvzap = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
    let rf = e.generate(&task.prompt, full.as_ref(), &sp).unwrap();
    let rk = e.generate(&task.prompt, kvzap.as_ref(), &sp).unwrap();
    assert!(!rf.text.is_empty(), "full-cache run must generate tokens");
    assert_eq!(rf.compression, 0.0);
    assert_eq!(
        rf.text, rk.text,
        "KVzap-pruned generation must match the full-cache output"
    );
    assert!(rk.compression > 0.3, "pruning must remove a large fraction: {}", rk.compression);
    assert!(rk.compression < 0.99);
}

// ---------------------------------------------------------------------------
// Step-level session API (Sequence / prefill / decode_step)

/// A sequence that joins a running decode group mid-flight must produce
/// exactly the tokens it would produce alone — the per-slot decode is
/// independent, which is what makes continuous batching sound.
#[test]
fn sequence_joining_mid_decode_matches_single() {
    let e = engine();
    let mut rng = Rng::new(33);
    let t1 = workload::ruler_instance("niah_single_1", 200, &mut rng.fork(1));
    let t2 = workload::ruler_instance("niah_single_2", 180, &mut rng.fork(2));
    let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
    let sp = SamplingParams::greedy(8);
    let r1 = e.generate(&t1.prompt, policy.as_ref(), &sp).unwrap();
    let r2 = e.generate(&t2.prompt, policy.as_ref(), &sp).unwrap();

    // session API: s1 decodes alone for three steps, then s2 joins — the
    // persistent DecodeGroup reallocates when the bucket grows and s1's
    // resident rows survive the re-scatter
    let mut group = e.decode_group();
    let mut s1 = e.sequence(1, &t1.prompt, sp.clone());
    e.prefill(&mut s1, policy.as_ref()).unwrap();
    for _ in 0..3 {
        let mut set = vec![&mut s1];
        e.decode_step(&mut group, &mut set).unwrap();
    }
    let mut s2 = e.sequence(2, &t2.prompt, sp.clone());
    e.prefill(&mut s2, policy.as_ref()).unwrap();
    while !s1.is_done() || !s2.is_done() {
        let mut set: Vec<&mut Sequence> = vec![];
        if !s1.is_done() {
            set.push(&mut s1);
        }
        if !s2.is_done() {
            set.push(&mut s2);
        }
        e.decode_step(&mut group, &mut set).unwrap();
    }
    assert_eq!(e.finish(&s1).text, r1.text, "joined sequence must match single decode");
    assert_eq!(e.finish(&s2).text, r2.text, "late joiner must match single decode");
}

/// Device-resident KV cache accounting: with a no-eviction policy, a
/// steady-state decode step transfers only the decoded `[L, H, d_head]`
/// row per sequence — zero KV uploads and zero mask updates after the
/// join. (Uses a private engine so other tests' traffic cannot leak into
/// the counters.)
#[test]
fn resident_decode_transfers_only_the_decoded_row() {
    let e = Engine::new(Arc::new(Runtime::reference()));
    let mut rng = Rng::new(77);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let policy = policies::by_name("full", e.window()).unwrap();
    let mut sp = SamplingParams::greedy(40);
    sp.stop_at_newline = false;
    let mut s = e.sequence(1, &task.prompt, sp);
    e.prefill(&mut s, policy.as_ref()).unwrap();

    let mut group = e.decode_group();
    let mut set = vec![&mut s];
    e.decode_step(&mut group, &mut set).unwrap();
    let m = &e.rt.manifest.model;
    let row_bytes = 4 * 2 * (m.n_layers * m.n_kv_heads * m.d_head) as u64;
    let slot_bytes = 4 * 2 * (m.n_layers * m.n_kv_heads * m.t_max * m.d_head) as u64;
    let after_join = e.rt.transfer.snapshot();
    assert_eq!(after_join.mask_uploads, 1, "the join installs the mask exactly once");
    assert_eq!(
        after_join.kv_bytes_up,
        slot_bytes + 4 * (m.n_layers * m.n_kv_heads * m.t_max) as u64,
        "the join scatters the full slot plus its mask"
    );
    assert_eq!(after_join.kv_bytes_down, row_bytes, "the join step fetches one row");

    let mut steps = 0u64;
    for _ in 0..10 {
        if s.is_done() {
            break;
        }
        let mut set = vec![&mut s];
        e.decode_step(&mut group, &mut set).unwrap();
        steps += 1;
    }
    assert!(steps >= 4, "expected several live steady-state steps, got {steps}");
    let now = e.rt.transfer.snapshot();
    assert_eq!(
        now.mask_uploads, after_join.mask_uploads,
        "a no-eviction policy performs zero mask uploads after prefill/join"
    );
    assert_eq!(
        now.kv_bytes_up, after_join.kv_bytes_up,
        "steady-state decode uploads zero KV bytes"
    );
    assert_eq!(
        now.kv_bytes_down - after_join.kv_bytes_down,
        steps * row_bytes,
        "each step transfers exactly the decoded row per sequence"
    );
    assert_eq!(now.decode_steps, steps + 1);
}

/// An evicting policy refreshes a slot's mask exactly when the previous
/// step's evictions dirtied it (dirty-flag threading) — the upload count
/// is predicted exactly by replaying the protocol against the observed
/// per-step evictions.
#[test]
fn resident_decode_mask_refreshes_track_evictions() {
    let e = Engine::new(Arc::new(Runtime::reference()));
    let mut rng = Rng::new(78);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    // tau=100 evicts every token the moment it leaves the decode window
    let policy = policies::by_name("kvzap_mlp:100", e.window()).unwrap();
    let mut sp = SamplingParams::greedy(60);
    sp.stop_at_newline = false;
    let mut s = e.sequence(1, &task.prompt, sp);
    e.prefill(&mut s, policy.as_ref()).unwrap();
    let mut group = e.decode_group();
    let mut expected_uploads = 0u64;
    let mut pending_dirty = true; // prefill pruning dirtied the mask
    let mut total_evicted = 0usize;
    let mut joined = false;
    for _ in 0..(e.window() + 8) {
        if s.is_done() {
            break;
        }
        // protocol replay: the join installs the mask (consuming any
        // pending dirt); afterwards a refresh happens at the start of a
        // step iff the previous step evicted
        if !joined || pending_dirty {
            expected_uploads += 1;
        }
        joined = true;
        pending_dirty = false;
        let before = s.decode_evictions;
        let mut set = vec![&mut s];
        e.decode_step(&mut group, &mut set).unwrap();
        if s.decode_evictions > before {
            pending_dirty = true;
            total_evicted += s.decode_evictions - before;
        }
    }
    assert!(total_evicted > 0, "the aggressive threshold must evict during decode");
    let snap = e.rt.transfer.snapshot();
    assert_eq!(
        snap.mask_uploads, expected_uploads,
        "mask uploads must be driven by the dirty flag, not by step count"
    );
}

/// Join/leave/rejoin equivalence on the resident-cache path: a sequence
/// that joins a running group mid-decode, leaves for a few steps and
/// rejoins must produce bit-identical text and CacheStats to the same
/// sequence decoded solo (extends the PR 2 mid-decode join test).
#[test]
fn sequence_leaving_and_rejoining_matches_solo() {
    let e = engine();
    let mut rng = Rng::new(55);
    let t1 = workload::ruler_instance("niah_single_1", 200, &mut rng.fork(1));
    let t2 = workload::ruler_instance("niah_single_2", 180, &mut rng.fork(2));
    let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
    let mut sp = SamplingParams::greedy(12);
    sp.stop_at_newline = false;

    // solo references via the same session API
    let solo = |prompt: &str, id: u64| {
        let mut g = e.decode_group();
        let mut s = e.sequence(id, prompt, sp.clone());
        e.prefill(&mut s, policy.as_ref()).unwrap();
        while !s.is_done() {
            let mut set = vec![&mut s];
            e.decode_step(&mut g, &mut set).unwrap();
        }
        (e.finish(&s).text, s.cache_stats())
    };
    let (text1, stats1) = solo(&t1.prompt, 91);
    let (text2, stats2) = solo(&t2.prompt, 92);

    // interleaved run: s1+s2 together, s1 leaves, s2 alone (bucket shrinks
    // to b1 — full realloc), s1 rejoins (bucket grows back)
    let mut group = e.decode_group();
    let mut s1 = e.sequence(1, &t1.prompt, sp.clone());
    let mut s2 = e.sequence(2, &t2.prompt, sp.clone());
    e.prefill(&mut s1, policy.as_ref()).unwrap();
    e.prefill(&mut s2, policy.as_ref()).unwrap();
    for _ in 0..2 {
        let mut set: Vec<&mut Sequence> = vec![];
        if !s1.is_done() {
            set.push(&mut s1);
        }
        if !s2.is_done() {
            set.push(&mut s2);
        }
        if set.is_empty() {
            break;
        }
        e.decode_step(&mut group, &mut set).unwrap();
    }
    for _ in 0..3 {
        if s2.is_done() {
            break;
        }
        let mut set = vec![&mut s2];
        e.decode_step(&mut group, &mut set).unwrap();
    }
    while !s1.is_done() || !s2.is_done() {
        let mut set: Vec<&mut Sequence> = vec![];
        if !s1.is_done() {
            set.push(&mut s1);
        }
        if !s2.is_done() {
            set.push(&mut s2);
        }
        e.decode_step(&mut group, &mut set).unwrap();
    }
    assert_eq!(e.finish(&s1).text, text1, "leave/rejoin must not change s1's tokens");
    assert_eq!(e.finish(&s2).text, text2, "shrink/grow reallocs must not change s2's tokens");
    assert_eq!(s1.cache_stats(), stats1, "s1 CacheStats must match the solo run");
    assert_eq!(s2.cache_stats(), stats2, "s2 CacheStats must match the solo run");
}

// ---------------------------------------------------------------------------
// Batcher-level

fn recv_done(rx: &std::sync::mpsc::Receiver<SeqEvent>) -> Response {
    loop {
        match rx
            .recv_timeout(std::time::Duration::from_secs(120))
            .expect("batcher must answer")
        {
            SeqEvent::Done(r) => return r,
            SeqEvent::Token { .. } => {}
        }
    }
}

/// Regression test for the group-static batcher bug where the leader's
/// SamplingParams silently replaced every follower's: two concurrent
/// requests with different `max_new` must come back with the lengths (and
/// texts) of their individual runs.
#[test]
fn batcher_honors_per_request_sampling_params() {
    let e = engine();
    let mut rng = Rng::new(21);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let full = policies::by_name("full", e.window()).unwrap();
    let sp_short = SamplingParams::greedy(2);
    let sp_long = SamplingParams::greedy(16);
    let r_short = e.generate(&task.prompt, full.as_ref(), &sp_short).unwrap();
    let r_long = e.generate(&task.prompt, full.as_ref(), &sp_long).unwrap();
    assert_ne!(
        r_short.tokens_out, r_long.tokens_out,
        "reference lengths must differ for this regression test to bite"
    );

    let batcher =
        Batcher::start(e.clone(), BatcherConfig { max_batch: 4, max_wait_us: 50_000 });
    let (tx1, rx1) = std::sync::mpsc::channel();
    let (tx2, rx2) = std::sync::mpsc::channel();
    batcher
        .submit(Request {
            prompt: task.prompt.clone(),
            policy: PolicySpec::Full,
            sp: sp_short.clone(),
            stream: false,
            events: tx1,
        })
        .unwrap();
    batcher
        .submit(Request {
            prompt: task.prompt.clone(),
            policy: PolicySpec::Full,
            sp: sp_long.clone(),
            stream: false,
            events: tx2,
        })
        .unwrap();
    let d1 = recv_done(&rx1);
    let d2 = recv_done(&rx2);
    assert!(d1.error.is_none(), "{:?}", d1.error);
    assert!(d2.error.is_none(), "{:?}", d2.error);
    assert_eq!(d1.tokens_out, r_short.tokens_out, "leader max_new must not leak to others");
    assert_eq!(d2.tokens_out, r_long.tokens_out, "follower max_new must be honored");
    assert_eq!(d1.text, r_short.text);
    assert_eq!(d2.text, r_long.text);
}

/// Cancellation frees the slot between steps and reports its reason; the
/// batcher keeps serving afterwards.
#[test]
fn batcher_cancel_frees_slot_and_reports_reason() {
    let e = engine();
    let batcher =
        Batcher::start(e.clone(), BatcherConfig { max_batch: 2, max_wait_us: 100_000 });
    let mut rng = Rng::new(22);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let mut sp = SamplingParams::greedy(200);
    sp.stop_at_newline = false;
    let (tx, rx) = std::sync::mpsc::channel();
    let id = batcher
        .submit(Request {
            prompt: task.prompt.clone(),
            policy: PolicySpec::Full,
            sp,
            stream: true,
            events: tx,
        })
        .unwrap();
    // lands during the batch-forming grace window, i.e. mid-schedule
    batcher.cancel(id).unwrap();
    let done = recv_done(&rx);
    assert_eq!(done.reason.as_deref(), Some("cancelled"), "{done:?}");
    assert!(done.error.is_none());
    assert!(done.tokens_out < 200);
    // the slot is reusable: a subsequent request completes normally
    let (tx2, rx2) = std::sync::mpsc::channel();
    batcher
        .submit(Request {
            prompt: task.prompt.clone(),
            policy: PolicySpec::Full,
            sp: SamplingParams::greedy(4),
            stream: false,
            events: tx2,
        })
        .unwrap();
    let d2 = recv_done(&rx2);
    assert!(d2.error.is_none(), "{:?}", d2.error);
    assert!(d2.tokens_out >= 1);
}

// ---------------------------------------------------------------------------
// Server-level

#[test]
fn server_round_trip() {
    let e = engine();
    use kvzap::server::{Client, Server, ServerConfig};
    use kvzap::util::json::Json;
    let cfg = ServerConfig {
        addr: "127.0.0.1:7961".into(),
        default_policy: "kvzap_mlp:-4".into(),
        max_batch: 2,
        max_wait_us: 500,
    };
    let server = Arc::new(Server::new(e, cfg));
    let srv = server.clone();
    let h = std::thread::spawn(move || srv.serve());
    std::thread::sleep(std::time::Duration::from_millis(150));
    let mut c = Client::connect("127.0.0.1:7961").unwrap();
    let resp = c
        .request(&Json::obj(vec![
            ("prompt", Json::str("XQZA = 12345. filler. Q XQZA\nA ")),
            ("max_new", Json::num(8.0)),
        ]))
        .unwrap();
    assert!(resp.get("error").is_none(), "{resp:?}");
    assert!(resp.get("text").is_some());
    assert!(resp.get("compression").and_then(|c| c.as_f64()).is_some());
    // structured stats: transfer accounting is visible over the protocol
    let stats = c.request(&Json::obj(vec![("cmd", Json::str("stats"))])).unwrap();
    let s = stats.get("stats").expect("stats object");
    assert_eq!(s.get("backend").and_then(|b| b.as_str()), Some("reference"));
    assert!(s.get("requests").and_then(|v| v.as_f64()).unwrap() >= 1.0);
    assert!(s.get("kv_bytes_up").and_then(|v| v.as_f64()).is_some());
    assert!(s.get("mask_uploads").and_then(|v| v.as_f64()).is_some());
    c.shutdown().unwrap();
    let _ = h.join();
}

/// The v2 protocol end to end: two concurrent clients with different
/// `max_new` and policies (one string-form, one structured-form) stream
/// tokens interleaved from the same decode group; one is cancelled
/// mid-stream and its slot is reused; a plain v1-style body still returns
/// the exact pre-redesign response shape.
#[test]
fn server_v2_streaming_cancel_and_backcompat() {
    use std::io::{BufRead as _, BufReader, Write as _};
    use std::net::TcpStream;
    use std::time::Instant;

    use kvzap::server::{Client, Server, ServerConfig};
    use kvzap::util::json::Json;

    let e = engine();
    let addr = "127.0.0.1:7963";
    let cfg = ServerConfig {
        addr: addr.into(),
        default_policy: "kvzap_mlp:-4".into(),
        max_batch: 2,
        max_wait_us: 100_000,
    };
    let server = Arc::new(Server::new(e.clone(), cfg));
    let srv = server.clone();
    let h = std::thread::spawn(move || srv.serve());
    std::thread::sleep(std::time::Duration::from_millis(150));

    let mut rng = Rng::new(44);
    let t_a = workload::ruler_instance("niah_single_1", 200, &mut rng.fork(0));
    let t_b = workload::ruler_instance("niah_single_2", 180, &mut rng.fork(1));

    // engine-direct reference for B (same policy the structured form names)
    let pol_b = policies::by_name("kvzap_linear:-6", e.window()).unwrap();
    let mut sp_b = SamplingParams::greedy(8);
    sp_b.stop_at_newline = false;
    let ref_b = e.generate(&t_b.prompt, pol_b.as_ref(), &sp_b).unwrap();

    // --- conn A: string-form policy, long stream, cancelled mid-way ------
    let a_stream = TcpStream::connect(addr).unwrap();
    let mut a_writer = a_stream.try_clone().unwrap();
    let a_spare = a_stream.try_clone().unwrap(); // for the v1 body later
    let a_reader = BufReader::new(a_stream);
    let req_a = Json::obj(vec![
        ("id", Json::str("a")),
        ("prompt", Json::str(t_a.prompt.clone())),
        ("policy", Json::str("kvzap_mlp:-4")),
        ("max_new", Json::num(200.0)),
        ("stop_newline", Json::Bool(false)),
        ("stream", Json::Bool(true)),
    ]);
    writeln!(a_writer, "{}", req_a.dump()).unwrap();
    let (a_sig_tx, a_sig_rx) = std::sync::mpsc::channel::<()>();
    let a_thread = std::thread::spawn(move || -> (Vec<Instant>, Json, Instant) {
        let mut token_times = vec![];
        for line in a_reader.lines() {
            let line = line.unwrap();
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(&line).unwrap();
            match j.get("event").and_then(|ev| ev.as_str()) {
                Some("token") => {
                    token_times.push(Instant::now());
                    if token_times.len() == 3 {
                        let _ = a_sig_tx.send(()); // a few tokens are out
                    }
                }
                Some("done") => return (token_times, j, Instant::now()),
                _ => {} // cancel ack
            }
        }
        panic!("conn A closed before its done event");
    });

    // --- conn B: structured-form policy, different max_new, full stream --
    let (b_sig_tx, b_sig_rx) = std::sync::mpsc::channel::<()>();
    let b_thread = std::thread::spawn(move || -> (Vec<Instant>, Json, Instant) {
        let b_stream = TcpStream::connect(addr).unwrap();
        let mut b_writer = b_stream.try_clone().unwrap();
        let b_reader = BufReader::new(b_stream);
        let req_b = Json::obj(vec![
            ("id", Json::str("b")),
            ("prompt", Json::str(t_b.prompt.clone())),
            (
                "policy",
                Json::parse(r#"{"kind": "kvzap", "surrogate": "linear", "tau": -6.0}"#)
                    .unwrap(),
            ),
            ("max_new", Json::num(8.0)),
            ("stop_newline", Json::Bool(false)),
            ("stream", Json::Bool(true)),
        ]);
        writeln!(b_writer, "{}", req_b.dump()).unwrap();
        let mut token_times = vec![];
        for line in b_reader.lines() {
            let line = line.unwrap();
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(&line).unwrap();
            match j.get("event").and_then(|ev| ev.as_str()) {
                Some("token") => {
                    token_times.push(Instant::now());
                    if token_times.len() == 1 {
                        let _ = b_sig_tx.send(()); // B's stream has begun
                    }
                }
                Some("done") => return (token_times, j, Instant::now()),
                _ => {}
            }
        }
        panic!("conn B closed before its done event");
    });

    // cancel A only once it has streamed a few tokens AND B's stream has
    // begun — this pins the interleaving deterministically (A's budget of
    // 200 tokens guarantees it is still mid-stream here)
    a_sig_rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
    b_sig_rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
    let cancel_cmd =
        Json::obj(vec![("cmd", Json::str("cancel")), ("id", Json::str("a"))]);
    writeln!(a_writer, "{}", cancel_cmd.dump()).unwrap();

    let (a_tokens, a_done, a_done_at) = a_thread.join().unwrap();
    let (b_tokens, b_done, _b_done_at) = b_thread.join().unwrap();

    // A was cancelled mid-stream: partial text, explicit reason
    assert_eq!(a_done.get("reason").and_then(|r| r.as_str()), Some("cancelled"));
    assert_eq!(a_done.get("id").and_then(|i| i.as_str()), Some("a"));
    let a_out = a_done.get("tokens_out").and_then(|t| t.as_usize()).unwrap();
    assert!((3..200).contains(&a_out), "cancelled after a few tokens, got {a_out}");

    // B streamed to completion and matches its single-run reference — the
    // structured policy object behaves exactly like the string form
    assert_eq!(b_done.get("id").and_then(|i| i.as_str()), Some("b"));
    assert_eq!(
        b_done.get("text").and_then(|t| t.as_str()).unwrap(),
        ref_b.text,
        "structured-form policy stream must match the engine-direct run"
    );
    assert_eq!(
        b_done.get("tokens_out").and_then(|t| t.as_usize()).unwrap(),
        b_tokens.len(),
        "one token event per accepted token"
    );
    if ref_b.tokens_out == 7 {
        // the engine-direct run exhausted its budget; the stream must
        // report the same reason
        assert_eq!(b_done.get("reason").and_then(|r| r.as_str()), Some("max_tokens"));
    }

    // interleaving: B's stream started while A was still streaming — with
    // the old group-static scheduler B's first token could only arrive
    // after A's stream had fully finished
    assert!(!a_tokens.is_empty() && !b_tokens.is_empty());
    assert!(
        b_tokens[0] < a_done_at,
        "token streams must interleave (continuous batching, not group-static)"
    );

    // A's freed slot is reusable immediately: a plain v1-style body on the
    // same connection (no id, no stream) gets the exact pre-redesign
    // response shape and the same text as an engine-direct run
    let ref_a = e
        .generate(
            &t_a.prompt,
            policies::by_name("kvzap_mlp:-4", e.window()).unwrap().as_ref(),
            &SamplingParams::greedy(4),
        )
        .unwrap();
    let req_v1 = Json::obj(vec![
        ("prompt", Json::str(t_a.prompt.clone())),
        ("max_new", Json::num(4.0)),
    ]);
    writeln!(a_writer, "{}", req_v1.dump()).unwrap();
    let mut a_tail = BufReader::new(a_spare);
    let resp = loop {
        let mut line = String::new();
        assert!(a_tail.read_line(&mut line).unwrap() > 0, "conn A closed");
        if line.trim().is_empty() {
            continue;
        }
        // skip any late cancel ack (even a torn one the joined reader
        // thread left behind in the kernel buffer)
        let j = match Json::parse(&line) {
            Ok(j) => j,
            Err(_) => continue,
        };
        if j.get("text").is_some() {
            break j;
        }
    };
    let keys: Vec<String> =
        resp.as_obj().unwrap().keys().cloned().collect();
    assert_eq!(
        keys,
        vec!["compression", "e2e_us", "text", "tokens_out"],
        "v1 body must return the exact pre-redesign response shape"
    );
    assert_eq!(resp.get("text").and_then(|t| t.as_str()).unwrap(), ref_a.text);

    // clean shutdown
    drop(a_writer);
    drop(a_tail);
    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    let _ = h.join();
}

// ---------------------------------------------------------------------------
// ScoreBuffer: Algorithm 1's delayed eviction (property tests)

/// The sliding window of the `w` most recent decoded positions is never
/// evicted, regardless of scores or threshold.
#[test]
fn prop_scorebuffer_window_never_evicted() {
    check(
        60,
        |r| {
            let w = r.below(12) + 2;
            let n = r.below(80) + w + 1;
            let tau = (r.f64() * 200.0 - 100.0) as f32;
            let scores: Vec<f32> =
                (0..n * 4).map(|_| (r.f64() * 20.0 - 10.0) as f32).collect();
            (w, n, tau, scores)
        },
        |&(w, n, tau, ref scores)| {
            let mut cache = PagedKvCache::new(2, 2, 256);
            let mut buf = ScoreBuffer::new(w, 2, 2);
            for i in 0..n {
                cache.fill(i + 1);
                buf.push_and_evict(i, scores[i * 4..(i + 1) * 4].to_vec(), tau, &mut cache);
                for p in i.saturating_sub(w - 1)..=i {
                    for l in 0..2 {
                        for h in 0..2 {
                            if !cache.is_kept(l, h, p) {
                                return Err(format!(
                                    "in-window pos {p} evicted at step {i} (w={w} tau={tau})"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Decode-time eviction matches an oracle recomputation on random score
/// streams: position i ends up evicted in head (l, h) iff it left the
/// window (i + w < n) and its score fell below tau.
#[test]
fn prop_scorebuffer_matches_oracle_recomputation() {
    check(
        60,
        |r| {
            let w = r.below(10) + 2;
            let n = r.below(100) + 1;
            let tau = (r.f64() * 12.0 - 6.0) as f32;
            let scores: Vec<f32> =
                (0..n * 4).map(|_| (r.f64() * 20.0 - 10.0) as f32).collect();
            (w, n, tau, scores)
        },
        |&(w, n, tau, ref scores)| {
            let mut cache = PagedKvCache::new(2, 2, 256);
            let mut buf = ScoreBuffer::new(w, 2, 2);
            for i in 0..n {
                cache.fill(i + 1);
                buf.push_and_evict(i, scores[i * 4..(i + 1) * 4].to_vec(), tau, &mut cache);
            }
            for i in 0..n {
                for l in 0..2 {
                    for h in 0..2 {
                        let evicted = i + w < n && scores[i * 4 + l * 2 + h] < tau;
                        if cache.is_kept(l, h, i) != !evicted {
                            return Err(format!(
                                "pos {i} head ({l},{h}): kept={} oracle_evicted={evicted} \
                                 (w={w} n={n} tau={tau})",
                                cache.is_kept(l, h, i)
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Thresholding is monotone in tau: anything evicted at a lower threshold
/// is also evicted at a higher one (on the same score stream).
#[test]
fn prop_scorebuffer_thresholding_monotone_in_tau() {
    check(
        40,
        |r| {
            let w = r.below(8) + 2;
            let n = r.below(60) + w + 1;
            let a = r.f64() * 12.0 - 6.0;
            let b = r.f64() * 12.0 - 6.0;
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            let scores: Vec<f32> =
                (0..n * 4).map(|_| (r.f64() * 20.0 - 10.0) as f32).collect();
            (w, n, lo as f32, hi as f32, scores)
        },
        |&(w, n, lo, hi, ref scores)| {
            let run = |tau: f32| -> PagedKvCache {
                let mut cache = PagedKvCache::new(2, 2, 256);
                let mut buf = ScoreBuffer::new(w, 2, 2);
                for i in 0..n {
                    cache.fill(i + 1);
                    buf.push_and_evict(i, scores[i * 4..(i + 1) * 4].to_vec(), tau, &mut cache);
                }
                cache
            };
            let (clo, chi) = (run(lo), run(hi));
            if clo.stats().kept < chi.stats().kept {
                return Err(format!(
                    "higher tau kept more: {} (tau={lo}) vs {} (tau={hi})",
                    clo.stats().kept,
                    chi.stats().kept
                ));
            }
            for i in 0..n {
                for l in 0..2 {
                    for h in 0..2 {
                        if !clo.is_kept(l, h, i) && chi.is_kept(l, h, i) {
                            return Err(format!(
                                "pos {i} ({l},{h}) evicted at tau={lo} but kept at tau={hi}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// PagedKvCache invariants (property tests)

fn ramp_tensor(l: usize, h: usize, t: usize, rng: &mut Rng) -> Tensor {
    let data: Vec<f32> = (0..l * h * t).map(|_| rng.f64() as f32).collect();
    Tensor::new(data, vec![l, 1, h, t]).unwrap()
}

#[test]
fn prop_budget_policies_meet_budget() {
    check(
        40,
        |r| {
            (
                r.below(4) + 1,                   // layers
                r.below(3) + 1,                   // heads
                r.below(200) + 40,                // prompt len
                [0.25, 0.5, 0.75][r.below(3)],    // keep frac
                r.next_u64(),
            )
        },
        |&(l, h, n, frac, seed)| {
            let mut rng = Rng::new(seed);
            let t = ramp_tensor(l, h, 256, &mut rng);
            let view = PrefillView {
                b: 0,
                score_lin: &t, score_mlp: &t, max_attn: &t, plus_attn: &t,
                cum_attn: &t, win_attn: &t, vnorm: &t, knorm: &t,
                oracle_s: Some(&t), oracle_s_plus: Some(&t),
            };
            for spec in ["h2o", "snapkv", "adakv", "kvzip", "knorm"] {
                let pol = policies::by_name(&format!("{spec}:{frac}"), 8).unwrap();
                let mut cache = PagedKvCache::new(l, h, 256);
                cache.fill(n);
                pol.prefill_prune(&view, n, &mut cache);
                let s = cache.stats();
                let kept_frac = s.kept as f64 / s.filled as f64;
                // budget ± window slack
                let slack = (8.0 + 2.0) / n as f64;
                if (kept_frac - frac).abs() > slack + 0.05 {
                    return Err(format!(
                        "{spec}: kept {kept_frac:.3} vs budget {frac} (l={l} h={h} n={n})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_window_always_protected() {
    check(
        40,
        |r| (r.below(150) + 30, r.next_u64(), [-100.0f32, 0.0, 100.0][r.below(3)]),
        |&(n, seed, tau)| {
            let mut rng = Rng::new(seed);
            let t = ramp_tensor(2, 2, 256, &mut rng);
            let view = PrefillView {
                b: 0,
                score_lin: &t, score_mlp: &t, max_attn: &t, plus_attn: &t,
                cum_attn: &t, win_attn: &t, vnorm: &t, knorm: &t,
                oracle_s: None, oracle_s_plus: None,
            };
            let window = 8;
            let pol = policies::KVzap::mlp(tau, window);
            let mut cache = PagedKvCache::new(2, 2, 256);
            cache.fill(n);
            pol.prefill_prune(&view, n, &mut cache);
            for l in 0..2 {
                for h in 0..2 {
                    for pos in n.saturating_sub(window)..n {
                        if !cache.is_kept(l, h, pos) {
                            return Err(format!("window pos {pos} evicted (n={n})"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_accounting_consistent() {
    check_with(
        Config { cases: 60, seed: 0xFEED },
        |r| {
            let n = r.below(120) + 16;
            let evictions: Vec<(usize, usize, usize)> = (0..r.below(200))
                .map(|_| (r.below(2), r.below(2), r.below(n)))
                .collect();
            (n, evictions)
        },
        |(n, ev)| {
            vec![(*n, shrink_vec(ev).pop().unwrap_or_default())]
        },
        |(n, evictions)| {
            let mut cache = PagedKvCache::new(2, 2, 256);
            cache.fill(*n);
            let mut expect = std::collections::HashSet::new();
            for &(l, h, p) in evictions {
                cache.evict(l, h, p);
                expect.insert((l, h, p));
            }
            let s = cache.stats();
            let want_kept = 2 * 2 * n - expect.len();
            if s.kept != want_kept {
                return Err(format!("kept {} want {}", s.kept, want_kept));
            }
            // mask agrees
            let mask = cache.mask_f32();
            let on = mask.iter().filter(|&&m| m > 0.0).count();
            if on != want_kept {
                return Err(format!("mask on {} want {}", on, want_kept));
            }
            Ok(())
        },
    );
}

/// retain/evict/fill vs CacheStats.compression() and the position-wise
/// mask_f32 round-trip, against a brute-force mirror of the kept set.
#[test]
fn prop_cache_retain_fill_mask_roundtrip() {
    check_with(
        Config { cases: 50, seed: 0xCAFE },
        |r| {
            let n = r.below(100) + 10;
            let grow = r.below(20);
            let modulus = r.below(5) + 2;
            let evictions: Vec<(usize, usize, usize)> = (0..r.below(100))
                .map(|_| (r.below(2), r.below(3), r.below(n + grow)))
                .collect();
            (n, grow, modulus, evictions)
        },
        |(n, grow, modulus, ev)| {
            vec![(*n, *grow, *modulus, shrink_vec(ev).pop().unwrap_or_default())]
        },
        |&(n, grow, modulus, ref evictions)| {
            let (layers, heads, t_max) = (2usize, 3usize, 160usize);
            let mut cache = PagedKvCache::new(layers, heads, t_max);
            let mut mirror = vec![false; layers * heads * t_max];
            cache.fill(n);
            for l in 0..layers {
                for h in 0..heads {
                    for p in 0..n {
                        mirror[(l * heads + h) * t_max + p] = true;
                    }
                }
            }
            // retain a modular pattern on head (0, 0)
            cache.retain(0, 0, n, |p| p % modulus == 0);
            for p in 0..n {
                if p % modulus != 0 {
                    mirror[p] = false;
                }
            }
            // grow the cache (decode fills), then apply random evictions
            cache.fill(n + grow);
            for l in 0..layers {
                for h in 0..heads {
                    for p in n..n + grow {
                        mirror[(l * heads + h) * t_max + p] = true;
                    }
                }
            }
            for &(l, h, p) in evictions {
                cache.evict(l, h, p);
                if p < n + grow {
                    mirror[(l * heads + h) * t_max + p] = false;
                }
            }
            // position-wise agreement: is_kept == mask_f32 == mirror
            let mask = cache.mask_f32();
            for l in 0..layers {
                for h in 0..heads {
                    for p in 0..t_max {
                        let i = (l * heads + h) * t_max + p;
                        if mirror[i] != cache.is_kept(l, h, p) {
                            return Err(format!("is_kept mismatch at ({l},{h},{p})"));
                        }
                        if mirror[i] != (mask[i] > 0.0) {
                            return Err(format!("mask mismatch at ({l},{h},{p})"));
                        }
                    }
                }
            }
            // aggregate accounting
            let kept = mirror.iter().filter(|&&k| k).count();
            let s = cache.stats();
            if s.kept != kept {
                return Err(format!("stats.kept {} want {kept}", s.kept));
            }
            if s.filled != layers * heads * (n + grow) {
                return Err(format!("stats.filled {}", s.filled));
            }
            let want_comp = 1.0 - kept as f64 / s.filled as f64;
            if (s.compression() - want_comp).abs() > 1e-12 {
                return Err(format!("compression {} want {want_comp}", s.compression()));
            }
            // per-head counts sum to the total
            let sum: usize = (0..layers)
                .flat_map(|l| (0..heads).map(move |h| (l, h)))
                .map(|(l, h)| cache.kept_in_head(l, h))
                .sum();
            if sum != kept {
                return Err(format!("kept_in_head sum {sum} want {kept}"));
            }
            Ok(())
        },
    );
}

/// Block-pool accounting: blocks freed by whole-block eviction return to
/// the pool immediately, and everything is released on drop (`with_pool`).
#[test]
fn pool_blocks_released_on_eviction_and_drop() {
    let pool = Arc::new(BlockPool::new(64));
    {
        let mut c = PagedKvCache::new(2, 2, 256).with_pool(pool.clone());
        assert!(c.fill(40)); // ceil(40/16) = 3 blocks x 4 heads = 12
        assert_eq!(pool.used(), 12);
        for p in 0..16 {
            c.evict(0, 0, p); // empties block 0 of head (0, 0)
        }
        assert_eq!(pool.used(), 11, "whole-block eviction returns the block");
        assert_eq!(c.stats().freed_blocks, 1);
    }
    assert_eq!(pool.free(), 64, "drop releases all residency");
    assert_eq!(pool.used(), 0);
}

#[test]
fn prop_tokenizer_roundtrip() {
    check(
        80,
        |r| {
            let n = r.below(100);
            (0..n)
                .map(|_| (r.below(94) + 32) as u8 as char)
                .collect::<String>()
        },
        |s| {
            let t = workload::ByteTokenizer::default();
            let ids = t.encode(s, 512);
            let back = t.decode(&ids[1..]);
            if &back == s {
                Ok(())
            } else {
                Err(format!("{s:?} -> {back:?}"))
            }
        },
    );
}

// ---------------------------------------------------------------------------
// Parallel blocked reference backend: scalar/parallel bitwise equivalence
// (PR 4). The scalar path (threads=1, naive kernels) is the oracle; the
// blocked + worker-pool path must reproduce it bit for bit at any thread
// count — same unit decomposition, same fixed-order stat merge, same
// fast_exp, and blocked kernels that preserve per-output reduction order.

use kvzap::runtime::{Arg, ParallelConfig};

/// Fetch every output of one prefill execution as raw f32 bit patterns.
fn prefill_bits(rt: &Runtime, name: &str, toks: &[i32], n: usize) -> Vec<Vec<u32>> {
    let pf = rt.artifact(name).unwrap();
    let t = pf.meta.t;
    let mut flat = vec![0i32; t];
    flat[..toks.len().min(t)].copy_from_slice(&toks[..toks.len().min(t)]);
    let lens = [n as i32];
    let outs = rt.exec(&pf, &[Arg::I32(&flat, &[1, t]), Arg::I32(&lens, &[1])]).unwrap();
    outs.iter()
        .zip(&pf.meta.outputs)
        .map(|(o, spec)| {
            rt.fetch_f32(o, &spec.shape).unwrap().data.iter().map(|v| v.to_bits()).collect()
        })
        .collect()
}

fn needle_tokens(len: usize) -> Vec<i32> {
    let mut toks = vec![0i32; len];
    toks[0] = 1;
    let body = "AAQX = 90210. the sky was clear. KB7 = 41. Q AAQX\nA ";
    for (i, tok) in toks.iter_mut().enumerate().skip(1) {
        *tok = body.as_bytes()[(i - 1) % body.len()] as i32;
    }
    toks
}

/// Tentpole acceptance: the parallel blocked compute path is bitwise
/// identical to the scalar path on every prefill output (logits, KV
/// caches, all eight statistics), across thread counts {1, 2, 8} — i.e.
/// the thread count never changes a single emitted bit.
#[test]
fn parallel_prefill_is_bitwise_identical_to_scalar() {
    let n = 300; // spans several 64-row blocks, not block-aligned
    let toks = needle_tokens(n);
    let scalar = Runtime::reference_with_options(512, ParallelConfig::scalar());
    let want = prefill_bits(&scalar, "prefill_b1_t384", &toks, n);
    for threads in [2usize, 8] {
        let rt = Runtime::reference_with_options(512, ParallelConfig::with_threads(threads));
        let got = prefill_bits(&rt, "prefill_b1_t384", &toks, n);
        assert_eq!(want.len(), got.len());
        for (oi, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a, b, "threads={threads}: prefill output {oi} diverged from scalar");
        }
    }
}

/// The kvzip oracle double pass (2x-length prefill, stats_from = n) is
/// also thread-invariant.
#[test]
fn parallel_kvzip_oracle_matches_scalar_bitwise() {
    let n = 200;
    let toks = needle_tokens(n);
    let lens = [n as i32];
    let mut runs: Vec<Vec<u32>> = vec![];
    for threads in [1usize, 4] {
        let rt = Runtime::reference_with_options(512, ParallelConfig::with_threads(threads));
        let art = rt.artifact("kvzip_score_t256").unwrap();
        let t = art.meta.t;
        let mut flat = vec![0i32; t];
        flat[..n].copy_from_slice(&toks);
        let outs = rt.exec(&art, &[Arg::I32(&flat, &[1, t]), Arg::I32(&lens, &[1])]).unwrap();
        let mut bits = vec![];
        for (o, spec) in outs.iter().zip(&art.meta.outputs) {
            bits.extend(rt.fetch_f32(o, &spec.shape).unwrap().data.iter().map(|v| v.to_bits()));
        }
        runs.push(bits);
    }
    assert_eq!(runs[0], runs[1], "kvzip oracle scores diverged between scalar and parallel");
}

/// Resident decode: slot-parallel execution must equal the serial scalar
/// path bit for bit — logits, surrogate scores and the in-place KV rows.
#[test]
fn parallel_decode_is_bitwise_identical_to_scalar() {
    let n = 40usize;
    let toks = needle_tokens(n);
    let mut per_cfg: Vec<(Vec<u32>, Vec<u32>)> = vec![];
    for threads in [1usize, 2, 8] {
        let rt = Runtime::reference_with_options(512, ParallelConfig::with_threads(threads));
        let pf = rt.artifact("prefill_b1_t128").unwrap();
        let t = pf.meta.t;
        let mut flat = vec![0i32; t];
        flat[..n].copy_from_slice(&toks);
        let lens = [n as i32];
        let pouts = rt.exec(&pf, &[Arg::I32(&flat, &[1, t]), Arg::I32(&lens, &[1])]).unwrap();
        let ki = pf.meta.output_index("kcache").unwrap();
        let vi = pf.meta.output_index("vcache").unwrap();
        let seq_k = rt.fetch_f32(&pouts[ki], &pf.meta.outputs[ki].shape).unwrap().data;
        let seq_v = rt.fetch_f32(&pouts[vi], &pf.meta.outputs[vi].shape).unwrap().data;
        let m = &rt.manifest.model;
        let (l, h, tm) = (m.n_layers, m.n_kv_heads, m.t_max);
        let mut mask = vec![0.0f32; l * h * tm];
        for li in 0..l {
            for hi in 0..h {
                for p in 0..n {
                    mask[(li * h + hi) * tm + p] = 1.0;
                }
            }
        }
        // a 4-slot group, every slot occupied -> slot-parallel on the
        // parallel configs, serial on scalar
        let dec = rt.artifact("decode_b4").unwrap();
        let db = dec.meta.batch;
        let hd = rt.kv_alloc(db).unwrap();
        for s in 0..db {
            rt.kv_scatter(&hd, s, &seq_k, &seq_v).unwrap();
            rt.kv_write_mask(&hd, s, &mask).unwrap();
        }
        let mut logits_bits = vec![];
        let mut kv_bits = vec![];
        let mut pos = vec![n as i32; db];
        let cur: Vec<i32> = (0..db).map(|s| b'0' as i32 + s as i32).collect();
        for _step in 0..3 {
            let outs = rt.exec_decode_resident(&dec, &cur, &pos, &hd).unwrap();
            let li = dec.meta.output_index("logits").unwrap();
            let ri = dec.meta.resident_output_index("logits").unwrap();
            let lg = rt.fetch_f32(&outs[ri], &dec.meta.outputs[li].shape).unwrap();
            logits_bits.extend(lg.data.iter().map(|v| v.to_bits()));
            let mut k_row = vec![0.0f32; hd.row_elems()];
            let mut v_row = vec![0.0f32; hd.row_elems()];
            for s in 0..db {
                rt.kv_fetch_row(&hd, s, pos[s] as usize, &mut k_row, &mut v_row).unwrap();
                kv_bits.extend(k_row.iter().chain(v_row.iter()).map(|v| v.to_bits()));
                pos[s] += 1;
            }
        }
        rt.kv_free(&hd);
        per_cfg.push((logits_bits, kv_bits));
    }
    for (i, threads) in [2usize, 8].iter().enumerate() {
        assert_eq!(per_cfg[0].0, per_cfg[i + 1].0, "threads={threads}: decode logits diverged");
        assert_eq!(per_cfg[0].1, per_cfg[i + 1].1, "threads={threads}: decoded KV rows diverged");
    }
}

/// End-to-end thread-count determinism at the engine level: full
/// generation (prefill + prune + batched resident decode) produces the
/// same text and compression on the scalar and parallel paths.
#[test]
fn generation_is_thread_count_invariant() {
    let mut texts: Vec<(String, String)> = vec![];
    for threads in [1usize, 4] {
        let rt = Runtime::reference_with_options(512, ParallelConfig::with_threads(threads));
        let e = Engine::new(Arc::new(rt));
        let mut rng = Rng::new(11);
        let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
        let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
        let sp = SamplingParams::greedy(8);
        let prompts = [task.prompt.as_str(), task.prompt.as_str(), task.prompt.as_str()];
        let rs = e.generate_batch(&prompts, policy.as_ref(), &sp).unwrap();
        texts.push((rs[0].text.clone(), format!("{:.6}", rs[0].compression)));
    }
    assert_eq!(texts[0], texts[1], "generation must not depend on the thread count");
}

/// The larger-capacity manifests grow the prefill bucket grid so a
/// 2048-token context prefills in one pass (what bench_prefill sweeps).
#[test]
fn extended_prefill_buckets_resolve_long_contexts() {
    let rt = Runtime::reference_with_options(2048, ParallelConfig::scalar());
    assert_eq!(rt.manifest.prefill_bucket(2048, 1).as_deref(), Some("prefill_b1_t2048"));
    assert_eq!(rt.manifest.prefill_bucket(600, 1).as_deref(), Some("prefill_b1_t1024"));
    // the kvzip oracle grid grows in lockstep, so every admissible prompt
    // stays oracle-scorable (max_prompt <= max kvzip bucket)
    assert_eq!(rt.manifest.kvzip_bucket(2048).as_deref(), Some("kvzip_score_t2048"));
    assert_eq!(rt.manifest.kvzip_bucket(600).as_deref(), Some("kvzip_score_t1024"));
    let toks = needle_tokens(1024);
    let bits = prefill_bits(&rt, "prefill_b1_t1024", &toks, 1024);
    assert!(!bits[0].is_empty(), "long-context prefill executes");
    // default manifest is unchanged
    assert_eq!(engine().rt.manifest.buckets.prefill_t, vec![128, 256, 384, 512]);
}
