//! Integration tests over the full rust stack (runtime + coordinator +
//! policies + server). Tests that need compiled artifacts skip gracefully
//! when artifacts/ is absent; `make test` runs after `make artifacts` so
//! they execute in CI order.

use std::sync::Arc;

use kvzap::coordinator::{Engine, SamplingParams};
use kvzap::kvcache::PagedKvCache;
use kvzap::policies::{self, PrefillView, PrunePolicy};
use kvzap::runtime::{Runtime, Tensor};
use kvzap::util::propcheck::{check, check_with, shrink_vec, Config};
use kvzap::util::rng::Rng;
use kvzap::workload;

fn engine() -> Option<Arc<Engine>> {
    let dir = kvzap::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    static ENGINE: once_cell::sync::OnceCell<Arc<Engine>> = once_cell::sync::OnceCell::new();
    Some(
        ENGINE
            .get_or_init(|| Arc::new(Engine::new(Arc::new(Runtime::load(dir).unwrap()))))
            .clone(),
    )
}

// ---------------------------------------------------------------------------
// Runtime-level

#[test]
fn manifest_buckets_resolve() {
    let Some(e) = engine() else { return };
    let m = &e.rt.manifest;
    assert!(m.prefill_bucket(100, 1).is_some());
    assert!(m.prefill_bucket(m.model.t_max, 4).is_some());
    assert!(m.prefill_bucket(m.model.t_max + 1, 1).is_none());
    assert!(m.decode_bucket(1).is_some());
    assert!(m.kvzip_bucket(200).is_some());
}

#[test]
fn generate_full_cache_is_deterministic() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let policy = policies::by_name("full", e.window()).unwrap();
    let sp = SamplingParams::greedy(8);
    let a = e.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
    let b = e.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
    assert_eq!(a.text, b.text);
    assert_eq!(a.compression, 0.0, "full cache never compresses");
}

#[test]
fn kvzap_policy_compresses_and_still_generates() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(2);
    let task = workload::ruler_instance("niah_single_1", 220, &mut rng);
    let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
    let r = e
        .generate(&task.prompt, policy.as_ref(), &SamplingParams::greedy(8))
        .unwrap();
    assert!(r.compression > 0.05, "tau=-4 should evict something: {}", r.compression);
    assert!(r.compression < 0.99);
}

#[test]
fn higher_threshold_compresses_more() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(3);
    let task = workload::ruler_instance("niah_multikey_1", 220, &mut rng);
    let sp = SamplingParams::greedy(4);
    let mut last = -1.0;
    for tau in [-8.0f64, -4.0, -1.0] {
        let p = policies::by_name(&format!("kvzap_mlp:{tau}"), e.window()).unwrap();
        let r = e.generate(&task.prompt, p.as_ref(), &sp).unwrap();
        assert!(
            r.compression >= last - 1e-9,
            "compression must be monotone in tau: {} then {}",
            last,
            r.compression
        );
        last = r.compression;
    }
}

#[test]
fn oracle_policy_runs_double_pass() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(4);
    let task = workload::ruler_instance("niah_single_2", 180, &mut rng);
    let p = policies::by_name("kvzip_plus:0.5", e.window()).unwrap();
    let r = e.generate(&task.prompt, p.as_ref(), &SamplingParams::greedy(4)).unwrap();
    assert!(r.oracle_us > 0, "oracle pass must have run");
    // budget 0.5 with window protection -> roughly half removed
    assert!(r.compression > 0.3 && r.compression < 0.6, "{}", r.compression);
}

#[test]
fn batched_generation_matches_single() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(5);
    let tasks: Vec<_> = (0..3)
        .map(|i| workload::ruler_instance("niah_single_1", 200, &mut rng.fork(i)))
        .collect();
    let p = policies::by_name("full", e.window()).unwrap();
    let sp = SamplingParams::greedy(6);
    let singles: Vec<String> = tasks
        .iter()
        .map(|t| e.generate(&t.prompt, p.as_ref(), &sp).unwrap().text)
        .collect();
    let prompts: Vec<&str> = tasks.iter().map(|t| t.prompt.as_str()).collect();
    let batched = e.generate_batch(&prompts, p.as_ref(), &sp).unwrap();
    for (s, b) in singles.iter().zip(&batched) {
        assert_eq!(s, &b.text, "slot-batched decode must match single decode");
    }
}

#[test]
fn score_answer_full_beats_random_eviction() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(6);
    let task = workload::ruler_instance("niah_single_1", 220, &mut rng);
    let full = policies::by_name("full", e.window()).unwrap();
    let rand = policies::by_name("random:0.15", e.window()).unwrap();
    let (nll_full, c0) = e.score_answer(&task.prompt, &task.answer, full.as_ref()).unwrap();
    let (nll_rand, c1) = e.score_answer(&task.prompt, &task.answer, rand.as_ref()).unwrap();
    assert_eq!(c0, 0.0);
    assert!(c1 > 0.5);
    assert!(
        nll_rand > nll_full,
        "evicting 85% of the cache at random must hurt: full {nll_full} vs random {nll_rand}"
    );
}

#[test]
fn decode_time_eviction_happens_on_long_generation() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(7);
    let a = workload::aime_instance(&mut rng);
    // very aggressive threshold: everything below +inf gets evicted when
    // it leaves the window
    let p = policies::by_name("kvzap_mlp:100", e.window()).unwrap();
    let r = e
        .generate(&a.task.prompt, p.as_ref(), &SamplingParams::greedy(40))
        .unwrap();
    if r.tokens_out > e.window() + 2 {
        assert!(r.decode_evictions > 0, "decode-time evictions expected");
    }
}

// ---------------------------------------------------------------------------
// Server-level

#[test]
fn server_round_trip() {
    let Some(e) = engine() else { return };
    use kvzap::server::{Client, Server, ServerConfig};
    use kvzap::util::json::Json;
    let cfg = ServerConfig {
        addr: "127.0.0.1:7961".into(),
        default_policy: "kvzap_mlp:-4".into(),
        max_batch: 2,
        max_wait_us: 500,
    };
    let server = Arc::new(Server::new(e, cfg));
    let srv = server.clone();
    let h = std::thread::spawn(move || srv.serve());
    std::thread::sleep(std::time::Duration::from_millis(150));
    let mut c = Client::connect("127.0.0.1:7961").unwrap();
    let resp = c
        .request(&Json::obj(vec![
            ("prompt", Json::str("XQZA = 12345. filler. Q XQZA\nA ")),
            ("max_new", Json::num(8.0)),
        ]))
        .unwrap();
    assert!(resp.get("error").is_none(), "{resp:?}");
    assert!(resp.get("text").is_some());
    assert!(resp.get("compression").and_then(|c| c.as_f64()).is_some());
    c.shutdown().unwrap();
    let _ = h.join();
}

// ---------------------------------------------------------------------------
// Property tests (no artifacts needed)

fn ramp_tensor(l: usize, h: usize, t: usize, rng: &mut Rng) -> Tensor {
    let data: Vec<f32> = (0..l * h * t).map(|_| rng.f64() as f32).collect();
    Tensor::new(data, vec![l, 1, h, t]).unwrap()
}

#[test]
fn prop_budget_policies_meet_budget() {
    check(
        40,
        |r| {
            (
                r.below(4) + 1,                   // layers
                r.below(3) + 1,                   // heads
                r.below(200) + 40,                // prompt len
                [0.25, 0.5, 0.75][r.below(3)],    // keep frac
                r.next_u64(),
            )
        },
        |&(l, h, n, frac, seed)| {
            let mut rng = Rng::new(seed);
            let t = ramp_tensor(l, h, 256, &mut rng);
            let view = PrefillView {
                b: 0,
                score_lin: &t, score_mlp: &t, max_attn: &t, plus_attn: &t,
                cum_attn: &t, win_attn: &t, vnorm: &t, knorm: &t,
                oracle_s: Some(&t), oracle_s_plus: Some(&t),
            };
            for spec in ["h2o", "snapkv", "adakv", "kvzip", "knorm"] {
                let pol = policies::by_name(&format!("{spec}:{frac}"), 8).unwrap();
                let mut cache = PagedKvCache::new(l, h, 256);
                cache.fill(n);
                pol.prefill_prune(&view, n, &mut cache);
                let s = cache.stats();
                let kept_frac = s.kept as f64 / s.filled as f64;
                // budget ± window slack
                let slack = (8.0 + 2.0) / n as f64;
                if (kept_frac - frac).abs() > slack + 0.05 {
                    return Err(format!(
                        "{spec}: kept {kept_frac:.3} vs budget {frac} (l={l} h={h} n={n})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_window_always_protected() {
    check(
        40,
        |r| (r.below(150) + 30, r.next_u64(), [-100.0f32, 0.0, 100.0][r.below(3)]),
        |&(n, seed, tau)| {
            let mut rng = Rng::new(seed);
            let t = ramp_tensor(2, 2, 256, &mut rng);
            let view = PrefillView {
                b: 0,
                score_lin: &t, score_mlp: &t, max_attn: &t, plus_attn: &t,
                cum_attn: &t, win_attn: &t, vnorm: &t, knorm: &t,
                oracle_s: None, oracle_s_plus: None,
            };
            let window = 8;
            let pol = policies::KVzap::mlp(tau, window);
            let mut cache = PagedKvCache::new(2, 2, 256);
            cache.fill(n);
            pol.prefill_prune(&view, n, &mut cache);
            for l in 0..2 {
                for h in 0..2 {
                    for pos in n.saturating_sub(window)..n {
                        if !cache.is_kept(l, h, pos) {
                            return Err(format!("window pos {pos} evicted (n={n})"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_accounting_consistent() {
    check_with(
        Config { cases: 60, seed: 0xFEED },
        |r| {
            let n = r.below(120) + 16;
            let evictions: Vec<(usize, usize, usize)> = (0..r.below(200))
                .map(|_| (r.below(2), r.below(2), r.below(n)))
                .collect();
            (n, evictions)
        },
        |(n, ev)| {
            vec![(*n, shrink_vec(ev).pop().unwrap_or_default())]
        },
        |(n, evictions)| {
            let mut cache = PagedKvCache::new(2, 2, 256);
            cache.fill(*n);
            let mut expect = std::collections::HashSet::new();
            for &(l, h, p) in evictions {
                cache.evict(l, h, p);
                expect.insert((l, h, p));
            }
            let s = cache.stats();
            let want_kept = 2 * 2 * n - expect.len();
            if s.kept != want_kept {
                return Err(format!("kept {} want {}", s.kept, want_kept));
            }
            // mask agrees
            let mask = cache.mask_f32();
            let on = mask.iter().filter(|&&m| m > 0.0).count();
            if on != want_kept {
                return Err(format!("mask on {} want {}", on, want_kept));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tokenizer_roundtrip() {
    check(
        80,
        |r| {
            let n = r.below(100);
            (0..n)
                .map(|_| (r.below(94) + 32) as u8 as char)
                .collect::<String>()
        },
        |s| {
            let t = workload::ByteTokenizer::default();
            let ids = t.encode(s, 512);
            let back = t.decode(&ids[1..]);
            if &back == s {
                Ok(())
            } else {
                Err(format!("{s:?} -> {back:?}"))
            }
        },
    );
}
