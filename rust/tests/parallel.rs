//! Parallel blocked reference backend: scalar/parallel bitwise equivalence
//! (PR 4). The scalar path (threads=1, naive kernels) is the oracle; the
//! blocked + worker-pool path must reproduce it bit for bit at any thread
//! count — same unit decomposition, same fixed-order stat merge, same
//! fast_exp, and blocked kernels that preserve per-output reduction order.
//!
//! Split from the original tests/integration.rs — same tests, same names.

mod common;

use std::sync::Arc;

use common::{engine, needle_tokens, prefill_bits};
use kvzap::coordinator::{Engine, SamplingParams};
use kvzap::policies;
use kvzap::runtime::kernels::SimdMode;
use kvzap::runtime::{Arg, ParallelConfig, Runtime};
use kvzap::util::rng::Rng;
use kvzap::workload;

/// Tentpole acceptance: the parallel blocked compute path is bitwise
/// identical to the scalar path on every prefill output (logits, KV
/// caches, all eight statistics), across thread counts {1, 2, 8} — i.e.
/// the thread count never changes a single emitted bit.
#[test]
fn parallel_prefill_is_bitwise_identical_to_scalar() {
    let n = 300; // spans several 64-row blocks, not block-aligned
    let toks = needle_tokens(n);
    let scalar = Runtime::reference_with_options(512, ParallelConfig::scalar());
    let want = prefill_bits(&scalar, "prefill_b1_t384", &toks, n);
    for threads in [2usize, 8] {
        let rt = Runtime::reference_with_options(512, ParallelConfig::with_threads(threads));
        let got = prefill_bits(&rt, "prefill_b1_t384", &toks, n);
        assert_eq!(want.len(), got.len());
        for (oi, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a, b, "threads={threads}: prefill output {oi} diverged from scalar");
        }
    }
}

/// The kvzip oracle double pass (2x-length prefill, stats_from = n) is
/// also thread-invariant.
#[test]
fn parallel_kvzip_oracle_matches_scalar_bitwise() {
    let n = 200;
    let toks = needle_tokens(n);
    let lens = [n as i32];
    let mut runs: Vec<Vec<u32>> = vec![];
    for threads in [1usize, 4] {
        let rt = Runtime::reference_with_options(512, ParallelConfig::with_threads(threads));
        let art = rt.artifact("kvzip_score_t256").unwrap();
        let t = art.meta.t;
        let mut flat = vec![0i32; t];
        flat[..n].copy_from_slice(&toks);
        let outs = rt.exec(&art, &[Arg::I32(&flat, &[1, t]), Arg::I32(&lens, &[1])]).unwrap();
        let mut bits = vec![];
        for (o, spec) in outs.iter().zip(&art.meta.outputs) {
            bits.extend(rt.fetch_f32(o, &spec.shape).unwrap().data.iter().map(|v| v.to_bits()));
        }
        runs.push(bits);
    }
    assert_eq!(runs[0], runs[1], "kvzip oracle scores diverged between scalar and parallel");
}

/// Resident decode: slot-parallel execution must equal the serial scalar
/// path bit for bit — logits, surrogate scores and the in-place KV rows.
#[test]
fn parallel_decode_is_bitwise_identical_to_scalar() {
    let n = 40usize;
    let toks = needle_tokens(n);
    let mut per_cfg: Vec<(Vec<u32>, Vec<u32>)> = vec![];
    for threads in [1usize, 2, 8] {
        let rt = Runtime::reference_with_options(512, ParallelConfig::with_threads(threads));
        let pf = rt.artifact("prefill_b1_t128").unwrap();
        let t = pf.meta.t;
        let mut flat = vec![0i32; t];
        flat[..n].copy_from_slice(&toks);
        let lens = [n as i32];
        let pouts = rt.exec(&pf, &[Arg::I32(&flat, &[1, t]), Arg::I32(&lens, &[1])]).unwrap();
        let ki = pf.meta.output_index("kcache").unwrap();
        let vi = pf.meta.output_index("vcache").unwrap();
        let seq_k = rt.fetch_f32(&pouts[ki], &pf.meta.outputs[ki].shape).unwrap().data;
        let seq_v = rt.fetch_f32(&pouts[vi], &pf.meta.outputs[vi].shape).unwrap().data;
        let m = &rt.manifest.model;
        let (l, h, tm) = (m.n_layers, m.n_kv_heads, m.t_max);
        let mut mask = vec![0.0f32; l * h * tm];
        for li in 0..l {
            for hi in 0..h {
                for p in 0..n {
                    mask[(li * h + hi) * tm + p] = 1.0;
                }
            }
        }
        // a 4-slot group, every slot occupied -> slot-parallel on the
        // parallel configs, serial on scalar
        let dec = rt.artifact("decode_b4").unwrap();
        let db = dec.meta.batch;
        let hd = rt.kv_alloc(db).unwrap();
        for s in 0..db {
            rt.kv_scatter(&hd, s, &seq_k, &seq_v).unwrap();
            rt.kv_write_mask(&hd, s, &mask).unwrap();
        }
        let mut logits_bits = vec![];
        let mut kv_bits = vec![];
        let mut pos = vec![n as i32; db];
        let cur: Vec<i32> = (0..db).map(|s| b'0' as i32 + s as i32).collect();
        for _step in 0..3 {
            let outs = rt.exec_decode_resident(&dec, &cur, &pos, &hd).unwrap();
            let li = dec.meta.output_index("logits").unwrap();
            let ri = dec.meta.resident_output_index("logits").unwrap();
            let lg = rt.fetch_f32(&outs[ri], &dec.meta.outputs[li].shape).unwrap();
            logits_bits.extend(lg.data.iter().map(|v| v.to_bits()));
            let mut k_row = vec![0.0f32; hd.row_elems()];
            let mut v_row = vec![0.0f32; hd.row_elems()];
            for s in 0..db {
                rt.kv_fetch_row(&hd, s, pos[s] as usize, &mut k_row, &mut v_row).unwrap();
                kv_bits.extend(k_row.iter().chain(v_row.iter()).map(|v| v.to_bits()));
                pos[s] += 1;
            }
        }
        rt.kv_free(&hd);
        per_cfg.push((logits_bits, kv_bits));
    }
    for (i, threads) in [2usize, 8].iter().enumerate() {
        assert_eq!(per_cfg[0].0, per_cfg[i + 1].0, "threads={threads}: decode logits diverged");
        assert_eq!(per_cfg[0].1, per_cfg[i + 1].1, "threads={threads}: decoded KV rows diverged");
    }
}

/// End-to-end thread-count determinism at the engine level: full
/// generation (prefill + prune + batched resident decode) produces the
/// same text and compression on the scalar and parallel paths.
#[test]
fn generation_is_thread_count_invariant() {
    let mut texts: Vec<(String, String)> = vec![];
    for threads in [1usize, 4] {
        let rt = Runtime::reference_with_options(512, ParallelConfig::with_threads(threads));
        let e = Engine::new(Arc::new(rt));
        let mut rng = Rng::new(11);
        let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
        let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
        let sp = SamplingParams::greedy(8);
        let prompts = [task.prompt.as_str(), task.prompt.as_str(), task.prompt.as_str()];
        let rs = e.generate_batch(&prompts, policy.as_ref(), &sp).unwrap();
        texts.push((rs[0].text.clone(), format!("{:.6}", rs[0].compression)));
    }
    assert_eq!(texts[0], texts[1], "generation must not depend on the thread count");
}

/// The SIMD lanes preserve the blocked path's bitwise contract: every
/// prefill output (logits, KV caches, all eight statistics) is identical
/// between simd=scalar and simd=auto at the same thread count — the
/// mul-then-add lanes keep each output's reduction order, so dispatch
/// never changes a single emitted bit. On hosts where auto resolves to
/// scalar this degenerates to a self-comparison, which still pins the
/// dispatch plumbing.
#[test]
fn simd_prefill_is_bitwise_identical_to_blocked_scalar() {
    let n = 300; // spans several 64-row blocks, not block-aligned
    let toks = needle_tokens(n);
    let rt = Runtime::reference_with_options(
        512,
        ParallelConfig::with_threads(4).with_simd(SimdMode::Scalar),
    );
    let want = prefill_bits(&rt, "prefill_b1_t384", &toks, n);
    let rt = Runtime::reference_with_options(
        512,
        ParallelConfig::with_threads(4).with_simd(SimdMode::Auto),
    );
    let got = prefill_bits(&rt, "prefill_b1_t384", &toks, n);
    assert_eq!(want.len(), got.len());
    for (oi, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a, b, "simd=auto: prefill output {oi} diverged from blocked scalar");
    }
}

/// End-to-end SIMD-dispatch determinism at the engine level: full
/// generation (prefill + prune + batched resident decode) produces the
/// same text and compression whether the blocked microkernels run scalar
/// or through the AVX2/NEON lanes — the KVZAP_SIMD=scalar|auto twin of
/// [`generation_is_thread_count_invariant`].
#[test]
fn generation_is_simd_mode_invariant() {
    let mut texts: Vec<(String, String)> = vec![];
    for simd in [SimdMode::Scalar, SimdMode::Auto] {
        let cfg = ParallelConfig::with_threads(4).with_simd(simd);
        let rt = Runtime::reference_with_options(512, cfg);
        let e = Engine::new(Arc::new(rt));
        let mut rng = Rng::new(11);
        let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
        let policy = policies::by_name("kvzap_mlp:-4", e.window()).unwrap();
        let sp = SamplingParams::greedy(8);
        let prompts = [task.prompt.as_str(), task.prompt.as_str(), task.prompt.as_str()];
        let rs = e.generate_batch(&prompts, policy.as_ref(), &sp).unwrap();
        texts.push((rs[0].text.clone(), format!("{:.6}", rs[0].compression)));
    }
    assert_eq!(texts[0], texts[1], "generation must not depend on the SIMD mode");
}

/// The larger-capacity manifests grow the prefill bucket grid so a
/// 2048-token context prefills in one pass (what bench_prefill sweeps).
#[test]
fn extended_prefill_buckets_resolve_long_contexts() {
    let rt = Runtime::reference_with_options(2048, ParallelConfig::scalar());
    assert_eq!(rt.manifest.prefill_bucket(2048, 1).as_deref(), Some("prefill_b1_t2048"));
    assert_eq!(rt.manifest.prefill_bucket(600, 1).as_deref(), Some("prefill_b1_t1024"));
    // the kvzip oracle grid grows in lockstep, so every admissible prompt
    // stays oracle-scorable (max_prompt <= max kvzip bucket)
    assert_eq!(rt.manifest.kvzip_bucket(2048).as_deref(), Some("kvzip_score_t2048"));
    assert_eq!(rt.manifest.kvzip_bucket(600).as_deref(), Some("kvzip_score_t1024"));
    let toks = needle_tokens(1024);
    let bits = prefill_bits(&rt, "prefill_b1_t1024", &toks, 1024);
    assert!(!bits[0].is_empty(), "long-context prefill executes");
    // default manifest is unchanged
    assert_eq!(engine().rt.manifest.buckets.prefill_t, vec![128, 256, 384, 512]);
}
