//! Shared fixtures and helpers for the integration-test crates.
//!
//! Every test binary runs hermetically against the pure-Rust reference
//! backend ([`kvzap::runtime::reference`]) — no `make artifacts`, no
//! python, no skipping. See docs/TESTING.md for the tier map and the
//! determinism rules these tests enforce.

#![allow(dead_code)] // each test crate uses a subset of these helpers

use std::sync::Arc;

use kvzap::coordinator::{Engine, Response, SeqEvent};
use kvzap::runtime::{Arg, Runtime, Tensor};
use kvzap::util::rng::Rng;

/// Shared engine over the hermetic reference backend — always available.
pub fn engine() -> Arc<Engine> {
    static ENGINE: once_cell::sync::OnceCell<Arc<Engine>> = once_cell::sync::OnceCell::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new(Arc::new(Runtime::reference()))))
        .clone()
}

/// Wait (bounded) for a request's final [`Response`] on its event channel.
pub fn recv_done(rx: &std::sync::mpsc::Receiver<SeqEvent>) -> Response {
    loop {
        match rx
            .recv_timeout(std::time::Duration::from_secs(120))
            .expect("batcher must answer")
        {
            SeqEvent::Done(r) => return r,
            SeqEvent::Token { .. } => {}
        }
    }
}

/// Fetch every output of one prefill execution as raw f32 bit patterns.
pub fn prefill_bits(rt: &Runtime, name: &str, toks: &[i32], n: usize) -> Vec<Vec<u32>> {
    let pf = rt.artifact(name).unwrap();
    let t = pf.meta.t;
    let mut flat = vec![0i32; t];
    flat[..toks.len().min(t)].copy_from_slice(&toks[..toks.len().min(t)]);
    let lens = [n as i32];
    let outs = rt.exec(&pf, &[Arg::I32(&flat, &[1, t]), Arg::I32(&lens, &[1])]).unwrap();
    outs.iter()
        .zip(&pf.meta.outputs)
        .map(|(o, spec)| {
            rt.fetch_f32(o, &spec.shape).unwrap().data.iter().map(|v| v.to_bits()).collect()
        })
        .collect()
}

/// A deterministic needle-in-haystack token pattern of length `len`.
pub fn needle_tokens(len: usize) -> Vec<i32> {
    let mut toks = vec![0i32; len];
    toks[0] = 1;
    let body = "AAQX = 90210. the sky was clear. KB7 = 41. Q AAQX\nA ";
    for (i, tok) in toks.iter_mut().enumerate().skip(1) {
        *tok = body.as_bytes()[(i - 1) % body.len()] as i32;
    }
    toks
}

/// Random `[l, 1, h, t]` stats tensor for policy property tests.
pub fn ramp_tensor(l: usize, h: usize, t: usize, rng: &mut Rng) -> Tensor {
    let data: Vec<f32> = (0..l * h * t).map(|_| rng.f64() as f32).collect();
    Tensor::new(data, vec![l, 1, h, t]).unwrap()
}
