//! Policy-zoo integration tests over the reference backend: every
//! cataloged policy kind prunes deterministically and honours the
//! protected window, `keep_frac = 1` budget presses are metamorphically
//! equivalent to no pruning, and the Fast-KVzip gated decode path
//! degenerates to its two limits (never-evict, and plain KVzap when the
//! gate always agrees).

mod common;

use common::engine;
use kvzap::coordinator::SamplingParams;
use kvzap::policies::spec::CATALOG;
use kvzap::policies::PolicySpec;
use kvzap::util::rng::Rng;
use kvzap::workload;

/// A representative spec string for one catalog kind: the first string
/// form with mid-range parameters (0.5 reads as keep-fraction for budget
/// kinds and as a τ for threshold kinds — both parse).
fn mid_spec(kind_form: &str, has_params: bool) -> String {
    if has_params {
        format!("{kind_form}:0.5")
    } else {
        kind_form.to_string()
    }
}

#[test]
fn every_catalog_policy_is_deterministic_and_protects_the_window() {
    let e = engine();
    let w = e.window();
    let mut rng = Rng::new(11);
    let task = workload::ruler_instance("niah_single_1", 220, &mut rng);
    let sp = SamplingParams::greedy(6);
    for info in CATALOG {
        let spec = mid_spec(info.string_forms[0], !info.params.is_empty());
        let policy = PolicySpec::parse(&spec).unwrap().build(w);

        // generation is bit-deterministic per policy
        let a = e.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
        let b = e.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
        assert_eq!(a.text, b.text, "{spec}: text must be deterministic");
        assert_eq!(
            a.compression.to_bits(),
            b.compression.to_bits(),
            "{spec}: compression must be deterministic"
        );

        // the protected window survives prefill pruning for every policy
        let mut s = e.sequence(1, &task.prompt, sp.clone());
        e.prefill(&mut s, policy.as_ref()).unwrap();
        let cache = s.cache();
        let n = s.prompt_len();
        assert!(n > w + 2, "prompt too short to exercise the window");
        for p in n.saturating_sub(w)..n {
            for l in 0..cache.layers {
                for h in 0..cache.heads {
                    assert!(
                        cache.is_kept(l, h, p),
                        "{spec}: window position {p}/{n} evicted at (l={l}, h={h})"
                    );
                }
            }
        }
    }
}

/// Metamorphic relation: a budget press told to keep everything must be
/// indistinguishable from the full cache — same text, zero compression.
#[test]
fn keep_frac_one_budget_presses_match_full() {
    let e = engine();
    let mut rng = Rng::new(12);
    let task = workload::ruler_instance("niah_multikey_1", 220, &mut rng);
    let sp = SamplingParams::greedy(8);
    let full = PolicySpec::parse("full").unwrap().build(e.window());
    let reference = e.generate(&task.prompt, full.as_ref(), &sp).unwrap();
    for info in CATALOG {
        if !info.params.iter().any(|p| p.name == "keep_frac") {
            continue;
        }
        let spec = format!("{}:1", info.string_forms[0]);
        let policy = PolicySpec::parse(&spec).unwrap().build(e.window());
        let r = e.generate(&task.prompt, policy.as_ref(), &sp).unwrap();
        assert_eq!(r.compression, 0.0, "{spec}: keep_frac=1 must not evict");
        assert_eq!(r.decode_evictions, 0, "{spec}: budget presses never decode-evict");
        assert_eq!(r.text, reference.text, "{spec}: keep_frac=1 must match full");
    }
}

/// A gate threshold no score can undercut makes Fast-KVzip a no-op even
/// with an evict-everything primary τ: eviction requires *both* surrogates
/// to agree.
#[test]
fn fastkvzip_unreachable_gate_never_evicts() {
    let e = engine();
    let mut rng = Rng::new(13);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let mut sp = SamplingParams::greedy(e.window() + 8);
    sp.stop_at_newline = false;
    let full = PolicySpec::parse("full").unwrap().build(e.window());
    let gated = PolicySpec::parse("fastkvzip:100:-10000").unwrap().build(e.window());
    let a = e.generate(&task.prompt, full.as_ref(), &sp).unwrap();
    let b = e.generate(&task.prompt, gated.as_ref(), &sp).unwrap();
    assert_eq!(b.compression, 0.0, "gate at -10000 must veto every eviction");
    assert_eq!(b.decode_evictions, 0);
    assert_eq!(a.text, b.text);
}

/// With the gate at the same (extreme) τ as the primary, the gate always
/// agrees and Fast-KVzip degenerates to plain KVzap-mlp — bitwise: same
/// text, same compression, same decode eviction count. This drives the
/// whole gated decode path (margin seeding at prefill, both-surrogate
/// fetch, deferred agreement eviction) end to end.
#[test]
fn fastkvzip_agreeing_gate_matches_plain_kvzap() {
    let e = engine();
    let mut rng = Rng::new(14);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let mut sp = SamplingParams::greedy(e.window() + 8);
    sp.stop_at_newline = false;
    let plain = PolicySpec::parse("kvzap_mlp:100").unwrap().build(e.window());
    let gated = PolicySpec::parse("fastkvzip:100:100").unwrap().build(e.window());
    let a = e.generate(&task.prompt, plain.as_ref(), &sp).unwrap();
    let b = e.generate(&task.prompt, gated.as_ref(), &sp).unwrap();
    assert_eq!(a.text, b.text, "agreeing gate must not change decoding");
    assert_eq!(a.compression.to_bits(), b.compression.to_bits());
    assert_eq!(a.decode_evictions, b.decode_evictions);
    assert!(a.decode_evictions > 0, "tau=100 must actually evict during decode");
}
