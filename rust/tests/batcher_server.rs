//! Batcher- and server-level integration tests: continuous batching with
//! per-request params, cancellation, the v2 streaming protocol over TCP,
//! the headless in-process transport, and the server error paths.
//!
//! Split from the original tests/integration.rs — same tests, same names —
//! plus the error-path and headless-transport coverage.

mod common;

use std::sync::Arc;

use common::{engine, recv_done};
use kvzap::coordinator::{Batcher, BatcherConfig, Request, SamplingParams};
use kvzap::policies::{self, PolicySpec};
use kvzap::server::{Client, HeadlessServer, Server, ServerConfig};
use kvzap::util::json::Json;
use kvzap::util::rng::Rng;
use kvzap::workload;

// ---------------------------------------------------------------------------
// Batcher-level

/// Regression test for the group-static batcher bug where the leader's
/// SamplingParams silently replaced every follower's: two concurrent
/// requests with different `max_new` must come back with the lengths (and
/// texts) of their individual runs.
#[test]
fn batcher_honors_per_request_sampling_params() {
    let e = engine();
    let mut rng = Rng::new(21);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let full = policies::by_name("full", e.window()).unwrap();
    let sp_short = SamplingParams::greedy(2);
    let sp_long = SamplingParams::greedy(16);
    let r_short = e.generate(&task.prompt, full.as_ref(), &sp_short).unwrap();
    let r_long = e.generate(&task.prompt, full.as_ref(), &sp_long).unwrap();
    assert_ne!(
        r_short.tokens_out, r_long.tokens_out,
        "reference lengths must differ for this regression test to bite"
    );

    let batcher =
        Batcher::start(e.clone(), BatcherConfig { max_batch: 4, max_wait_us: 50_000 });
    let (tx1, rx1) = std::sync::mpsc::channel();
    let (tx2, rx2) = std::sync::mpsc::channel();
    batcher
        .submit(Request {
            prompt: task.prompt.clone(),
            policy: PolicySpec::Full,
            sp: sp_short.clone(),
            stream: false,
            events: tx1,
        })
        .unwrap();
    batcher
        .submit(Request {
            prompt: task.prompt.clone(),
            policy: PolicySpec::Full,
            sp: sp_long.clone(),
            stream: false,
            events: tx2,
        })
        .unwrap();
    let d1 = recv_done(&rx1);
    let d2 = recv_done(&rx2);
    assert!(d1.error.is_none(), "{:?}", d1.error);
    assert!(d2.error.is_none(), "{:?}", d2.error);
    assert_eq!(d1.tokens_out, r_short.tokens_out, "leader max_new must not leak to others");
    assert_eq!(d2.tokens_out, r_long.tokens_out, "follower max_new must be honored");
    assert_eq!(d1.text, r_short.text);
    assert_eq!(d2.text, r_long.text);
}

/// Cancellation frees the slot between steps and reports its reason; the
/// batcher keeps serving afterwards.
#[test]
fn batcher_cancel_frees_slot_and_reports_reason() {
    let e = engine();
    let batcher =
        Batcher::start(e.clone(), BatcherConfig { max_batch: 2, max_wait_us: 100_000 });
    let mut rng = Rng::new(22);
    let task = workload::ruler_instance("niah_single_1", 200, &mut rng);
    let mut sp = SamplingParams::greedy(200);
    sp.stop_at_newline = false;
    let (tx, rx) = std::sync::mpsc::channel();
    let id = batcher
        .submit(Request {
            prompt: task.prompt.clone(),
            policy: PolicySpec::Full,
            sp,
            stream: true,
            events: tx,
        })
        .unwrap();
    // lands during the batch-forming grace window, i.e. mid-schedule
    batcher.cancel(id).unwrap();
    let done = recv_done(&rx);
    assert_eq!(done.reason.as_deref(), Some("cancelled"), "{done:?}");
    assert!(done.error.is_none());
    assert!(done.tokens_out < 200);
    // the slot is reusable: a subsequent request completes normally
    let (tx2, rx2) = std::sync::mpsc::channel();
    batcher
        .submit(Request {
            prompt: task.prompt.clone(),
            policy: PolicySpec::Full,
            sp: SamplingParams::greedy(4),
            stream: false,
            events: tx2,
        })
        .unwrap();
    let d2 = recv_done(&rx2);
    assert!(d2.error.is_none(), "{:?}", d2.error);
    assert!(d2.tokens_out >= 1);
}

// ---------------------------------------------------------------------------
// Server-level (TCP)

#[test]
fn server_round_trip() {
    let e = engine();
    let cfg = ServerConfig {
        addr: "127.0.0.1:7961".into(),
        default_policy: "kvzap_mlp:-4".into(),
        max_batch: 2,
        max_wait_us: 500,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(e, cfg));
    let srv = server.clone();
    let h = std::thread::spawn(move || srv.serve());
    std::thread::sleep(std::time::Duration::from_millis(150));
    let mut c = Client::connect("127.0.0.1:7961").unwrap();
    let resp = c
        .request(&Json::obj(vec![
            ("prompt", Json::str("XQZA = 12345. filler. Q XQZA\nA ")),
            ("max_new", Json::num(8.0)),
        ]))
        .unwrap();
    assert!(resp.get("error").is_none(), "{resp:?}");
    assert!(resp.get("text").is_some());
    assert!(resp.get("compression").and_then(|v| v.as_f64()).is_some());
    // structured stats: transfer accounting is visible over the protocol
    let stats = c.request(&Json::obj(vec![("cmd", Json::str("stats"))])).unwrap();
    let s = stats.get("stats").expect("stats object");
    assert_eq!(s.get("backend").and_then(|b| b.as_str()), Some("reference"));
    assert!(s.get("requests").and_then(|v| v.as_f64()).unwrap() >= 1.0);
    assert!(s.get("kv_bytes_up").and_then(|v| v.as_f64()).is_some());
    assert!(s.get("mask_uploads").and_then(|v| v.as_f64()).is_some());
    c.shutdown().unwrap();
    let _ = h.join();
}

/// The v2 protocol end to end: two concurrent clients with different
/// `max_new` and policies (one string-form, one structured-form) stream
/// tokens interleaved from the same decode group; one is cancelled
/// mid-stream and its slot is reused; a plain v1-style body still returns
/// the exact pre-redesign response shape.
#[test]
fn server_v2_streaming_cancel_and_backcompat() {
    use std::io::{BufRead as _, BufReader, Write as _};
    use std::net::TcpStream;
    use std::time::Instant;

    let e = engine();
    let addr = "127.0.0.1:7963";
    let cfg = ServerConfig {
        addr: addr.into(),
        default_policy: "kvzap_mlp:-4".into(),
        max_batch: 2,
        max_wait_us: 100_000,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(e.clone(), cfg));
    let srv = server.clone();
    let h = std::thread::spawn(move || srv.serve());
    std::thread::sleep(std::time::Duration::from_millis(150));

    let mut rng = Rng::new(44);
    let t_a = workload::ruler_instance("niah_single_1", 200, &mut rng.fork(0));
    let t_b = workload::ruler_instance("niah_single_2", 180, &mut rng.fork(1));

    // engine-direct reference for B (same policy the structured form names)
    let pol_b = policies::by_name("kvzap_linear:-6", e.window()).unwrap();
    let mut sp_b = SamplingParams::greedy(8);
    sp_b.stop_at_newline = false;
    let ref_b = e.generate(&t_b.prompt, pol_b.as_ref(), &sp_b).unwrap();

    // --- conn A: string-form policy, long stream, cancelled mid-way ------
    let a_stream = TcpStream::connect(addr).unwrap();
    let mut a_writer = a_stream.try_clone().unwrap();
    let a_spare = a_stream.try_clone().unwrap(); // for the v1 body later
    let a_reader = BufReader::new(a_stream);
    let req_a = Json::obj(vec![
        ("id", Json::str("a")),
        ("prompt", Json::str(t_a.prompt.clone())),
        ("policy", Json::str("kvzap_mlp:-4")),
        ("max_new", Json::num(200.0)),
        ("stop_newline", Json::Bool(false)),
        ("stream", Json::Bool(true)),
    ]);
    writeln!(a_writer, "{}", req_a.dump()).unwrap();
    let (a_sig_tx, a_sig_rx) = std::sync::mpsc::channel::<()>();
    let a_thread = std::thread::spawn(move || -> (Vec<Instant>, Json, Instant) {
        let mut token_times = vec![];
        for line in a_reader.lines() {
            let line = line.unwrap();
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(&line).unwrap();
            match j.get("event").and_then(|ev| ev.as_str()) {
                Some("token") => {
                    token_times.push(Instant::now());
                    if token_times.len() == 3 {
                        let _ = a_sig_tx.send(()); // a few tokens are out
                    }
                }
                Some("done") => return (token_times, j, Instant::now()),
                _ => {} // cancel ack
            }
        }
        panic!("conn A closed before its done event");
    });

    // --- conn B: structured-form policy, different max_new, full stream --
    let (b_sig_tx, b_sig_rx) = std::sync::mpsc::channel::<()>();
    let b_thread = std::thread::spawn(move || -> (Vec<Instant>, Json, Instant) {
        let b_stream = TcpStream::connect(addr).unwrap();
        let mut b_writer = b_stream.try_clone().unwrap();
        let b_reader = BufReader::new(b_stream);
        let req_b = Json::obj(vec![
            ("id", Json::str("b")),
            ("prompt", Json::str(t_b.prompt.clone())),
            (
                "policy",
                Json::parse(r#"{"kind": "kvzap", "surrogate": "linear", "tau": -6.0}"#)
                    .unwrap(),
            ),
            ("max_new", Json::num(8.0)),
            ("stop_newline", Json::Bool(false)),
            ("stream", Json::Bool(true)),
        ]);
        writeln!(b_writer, "{}", req_b.dump()).unwrap();
        let mut token_times = vec![];
        for line in b_reader.lines() {
            let line = line.unwrap();
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(&line).unwrap();
            match j.get("event").and_then(|ev| ev.as_str()) {
                Some("token") => {
                    token_times.push(Instant::now());
                    if token_times.len() == 1 {
                        let _ = b_sig_tx.send(()); // B's stream has begun
                    }
                }
                Some("done") => return (token_times, j, Instant::now()),
                _ => {}
            }
        }
        panic!("conn B closed before its done event");
    });

    // cancel A only once it has streamed a few tokens AND B's stream has
    // begun — this pins the interleaving deterministically (A's budget of
    // 200 tokens guarantees it is still mid-stream here)
    a_sig_rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
    b_sig_rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
    let cancel_cmd =
        Json::obj(vec![("cmd", Json::str("cancel")), ("id", Json::str("a"))]);
    writeln!(a_writer, "{}", cancel_cmd.dump()).unwrap();

    let (a_tokens, a_done, a_done_at) = a_thread.join().unwrap();
    let (b_tokens, b_done, _b_done_at) = b_thread.join().unwrap();

    // A was cancelled mid-stream: partial text, explicit reason
    assert_eq!(a_done.get("reason").and_then(|r| r.as_str()), Some("cancelled"));
    assert_eq!(a_done.get("id").and_then(|i| i.as_str()), Some("a"));
    let a_out = a_done.get("tokens_out").and_then(|t| t.as_usize()).unwrap();
    assert!((3..200).contains(&a_out), "cancelled after a few tokens, got {a_out}");

    // B streamed to completion and matches its single-run reference — the
    // structured policy object behaves exactly like the string form
    assert_eq!(b_done.get("id").and_then(|i| i.as_str()), Some("b"));
    assert_eq!(
        b_done.get("text").and_then(|t| t.as_str()).unwrap(),
        ref_b.text,
        "structured-form policy stream must match the engine-direct run"
    );
    assert_eq!(
        b_done.get("tokens_out").and_then(|t| t.as_usize()).unwrap(),
        b_tokens.len(),
        "one token event per accepted token"
    );
    if ref_b.tokens_out == 7 {
        // the engine-direct run exhausted its budget; the stream must
        // report the same reason
        assert_eq!(b_done.get("reason").and_then(|r| r.as_str()), Some("max_tokens"));
    }

    // interleaving: B's stream started while A was still streaming — with
    // the old group-static scheduler B's first token could only arrive
    // after A's stream had fully finished
    assert!(!a_tokens.is_empty() && !b_tokens.is_empty());
    assert!(
        b_tokens[0] < a_done_at,
        "token streams must interleave (continuous batching, not group-static)"
    );

    // A's freed slot is reusable immediately: a plain v1-style body on the
    // same connection (no id, no stream) gets the exact pre-redesign
    // response shape and the same text as an engine-direct run
    let ref_a = e
        .generate(
            &t_a.prompt,
            policies::by_name("kvzap_mlp:-4", e.window()).unwrap().as_ref(),
            &SamplingParams::greedy(4),
        )
        .unwrap();
    let req_v1 = Json::obj(vec![
        ("prompt", Json::str(t_a.prompt.clone())),
        ("max_new", Json::num(4.0)),
    ]);
    writeln!(a_writer, "{}", req_v1.dump()).unwrap();
    let mut a_tail = BufReader::new(a_spare);
    let resp = loop {
        let mut line = String::new();
        assert!(a_tail.read_line(&mut line).unwrap() > 0, "conn A closed");
        if line.trim().is_empty() {
            continue;
        }
        // skip any late cancel ack (even a torn one the joined reader
        // thread left behind in the kernel buffer)
        let j = match Json::parse(&line) {
            Ok(j) => j,
            Err(_) => continue,
        };
        if j.get("text").is_some() {
            break j;
        }
    };
    let keys: Vec<String> =
        resp.as_obj().unwrap().keys().cloned().collect();
    assert_eq!(
        keys,
        vec!["compression", "e2e_us", "text", "tokens_out"],
        "v1 body must return the exact pre-redesign response shape"
    );
    assert_eq!(resp.get("text").and_then(|t| t.as_str()).unwrap(), ref_a.text);

    // clean shutdown
    drop(a_writer);
    drop(a_tail);
    let mut c = Client::connect(addr).unwrap();
    c.shutdown().unwrap();
    let _ = h.join();
}

// ---------------------------------------------------------------------------
// Server-level (headless transport + error paths)

fn headless_server() -> HeadlessServer {
    HeadlessServer::new(
        engine(),
        ServerConfig {
            addr: String::new(), // unused by the headless transport
            default_policy: "kvzap_mlp:-4".into(),
            max_batch: 2,
            max_wait_us: 500,
            ..ServerConfig::default()
        },
    )
}

/// The headless in-process transport runs the same v2 loop as TCP:
/// commands, generation, stats — and connections share one batcher.
#[test]
fn headless_transport_runs_the_v2_protocol() {
    let srv = headless_server();
    let c = srv.connect();
    let r = c.request(r#"{"cmd": "policies"}"#).unwrap();
    let n = r.get("policies").and_then(|p| p.as_arr()).map(|a| a.len()).unwrap_or(0);
    assert!(n >= 10, "policy catalog over headless: {n}");
    let r = c.request(r#"{"prompt": "KEY = 777. filler. Q KEY\nA ", "max_new": 6}"#).unwrap();
    assert!(r.get("error").is_none(), "{r:?}");
    assert!(r.get("text").is_some());
    let stats = c.request(r#"{"cmd": "stats"}"#).unwrap();
    let s = stats.get("stats").expect("stats object");
    assert_eq!(s.get("backend").and_then(|b| b.as_str()), Some("reference"));
    // a second connection shares the same batcher and engine
    let c2 = srv.connect();
    let r2 = c2.request(r#"{"prompt": "KEY = 777. filler. Q KEY\nA ", "max_new": 2}"#).unwrap();
    assert!(r2.get("error").is_none(), "{r2:?}");
}

/// Multi-shard headless server: the `stats` command aggregates counters
/// across shards (every summed field equals the sum of its per-shard
/// values in the `shard` breakdown), and repeating an identical
/// (prompt, policy) pair hits the shared cross-shard prefix cache.
#[test]
fn sharded_stats_aggregate_and_prefix_hits() {
    let srv = HeadlessServer::new_sharded(
        vec![engine(), engine()],
        ServerConfig {
            addr: String::new(), // unused by the headless transport
            default_policy: "kvzap_mlp:-4".into(),
            max_batch: 2,
            max_wait_us: 500,
            prefix_reuse: true,
            ..ServerConfig::default()
        },
    );
    let c = srv.connect();
    // same (prompt, policy) twice — the second prefill reuses the stored
    // snapshot — plus one distinct prompt that may land on either shard
    for prompt in [
        "KEY = 777. filler. Q KEY\nA ",
        "KEY = 777. filler. Q KEY\nA ",
        "OTHER = 31. pad pad pad. Q OTHER\nA ",
    ] {
        let req =
            Json::obj(vec![("prompt", Json::str(prompt)), ("max_new", Json::num(4.0))]);
        let r = c.request(&req.dump()).unwrap();
        assert!(r.get("error").is_none(), "{r:?}");
    }
    let stats = c.request(r#"{"cmd": "stats"}"#).unwrap();
    let s = stats.get("stats").expect("stats object");
    let per = s.get("shard").and_then(|v| v.as_arr()).expect("per-shard breakdown");
    assert_eq!(per.len(), 2, "one breakdown entry per shard");
    for (key, v) in s.as_obj().unwrap() {
        if matches!(
            key.as_str(),
            // non-summed: scalars, the breakdown itself, and the shared
            // prefix cache's set-level gauges (one cache, not per shard)
            "backend" | "shard" | "mean_compression" | "prefix_bytes" | "prefix_entries"
        ) {
            continue;
        }
        let total = v.as_f64().unwrap_or_else(|| panic!("non-numeric stat {key}"));
        let sum: f64 = per
            .iter()
            .map(|sh| sh.get(key).and_then(|x| x.as_f64()).unwrap_or(0.0))
            .sum();
        assert!(
            (total - sum).abs() < 1e-6,
            "stat '{key}': aggregate {total} != per-shard sum {sum}"
        );
    }
    assert_eq!(s.get("requests").and_then(|v| v.as_f64()), Some(3.0));
    assert!(
        s.get("prefix_hits").and_then(|v| v.as_f64()).unwrap() >= 1.0,
        "identical repeated prompt must hit the shared prefix cache: {s:?}"
    );
    // the shared cache's live gauges ride at the set level, once
    assert!(
        s.get("prefix_entries").and_then(|v| v.as_f64()).unwrap() >= 1.0,
        "stored snapshots must show in the entries gauge: {s:?}"
    );
    assert!(
        s.get("prefix_bytes").and_then(|v| v.as_f64()).unwrap() > 0.0,
        "stored snapshots must show in the bytes gauge: {s:?}"
    );
    assert_eq!(
        s.get("prefix_evictions").and_then(|v| v.as_f64()),
        Some(0.0),
        "unbounded cache must never evict: {s:?}"
    );
    // the cross-check also holds against direct per-engine counters
    let direct: u64 = srv
        .engines()
        .iter()
        .map(|e| e.metrics.requests.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    assert_eq!(direct, 3);
}

/// Malformed JSON, an unknown cmd, a cancel for an unknown id, and an
/// oversized prompt all return structured errors — and the connection
/// keeps serving afterwards instead of dropping.
#[test]
fn server_error_paths_return_structured_errors() {
    let srv = headless_server();
    let c = srv.connect();

    let r = c.request("{not json").unwrap();
    let msg = r.get("error").and_then(|v| v.as_str()).expect("error field");
    assert!(msg.contains("bad json"), "{msg}");

    let r = c.request(r#"{"cmd": "frobnicate"}"#).unwrap();
    let msg = r.get("error").and_then(|v| v.as_str()).expect("error field");
    assert!(msg.contains("unknown cmd"), "{msg}");

    let r = c.request(r#"{"cmd": "cancel", "id": "ghost"}"#).unwrap();
    assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(false));
    assert!(r.get("error").is_some(), "cancel of unknown id carries an error: {r:?}");

    // oversized prompt: rejected with a structured error (id echoed), not
    // silently truncated and not a dropped connection
    let max_prompt = engine().max_prompt();
    let huge = "x".repeat(max_prompt + 10);
    let req = Json::obj(vec![
        ("prompt", Json::str(huge)),
        ("max_new", Json::num(2.0)),
        ("id", Json::str("big")),
    ]);
    let r = c.request(&req.dump()).unwrap();
    let msg = r.get("error").and_then(|v| v.as_str()).expect("error field");
    assert!(msg.contains("prompt too long"), "{msg}");
    assert_eq!(r.get("id").and_then(|i| i.as_str()), Some("big"));

    // the connection survived all four: a normal request still works
    let r = c
        .request(r#"{"prompt": "XQZA = 12345. filler. Q XQZA\nA ", "max_new": 4}"#)
        .unwrap();
    assert!(r.get("error").is_none(), "{r:?}");
    assert!(r.get("text").is_some());
}

/// Per-tenant fair-share on the threaded (TCP/headless) path: a tenant
/// flooding requests past its in-flight cap backpressures *its own*
/// connection — it never holds more than `tenant_inflight` slots at once
/// and its submits park at the gate — while a second tenant at a light
/// offered load keeps dispatching and completing throughout.
#[test]
fn shardset_fair_share_caps_flooding_tenant_without_starving_light_one() {
    let srv = HeadlessServer::new(
        engine(),
        ServerConfig {
            addr: String::new(), // unused by the headless transport
            default_policy: "kvzap_mlp:-4".into(),
            max_batch: 2,
            max_wait_us: 500,
            tenant_inflight: 2,
            ..ServerConfig::default()
        },
    );

    // tenant "flood" fires 5 streaming requests back-to-back on one
    // connection; its protocol loop parks in the gate past 2 in flight
    let flood = srv.connect();
    for i in 0..5 {
        let req = format!(
            r#"{{"prompt": "KEY = 777. filler. Q KEY\nA ", "max_new": 16, "stop_newline": false, "stream": true, "id": "f{i}", "tenant": "flood"}}"#
        );
        flood.send_line(&req).unwrap();
    }

    // tenant "light" meanwhile gets both of its requests through on its
    // own connection — a blocking round trip each, so a completed reply
    // *is* the no-starvation evidence (a gated-out tenant would hang)
    let light = srv.connect();
    for i in 0..2 {
        let req = format!(
            r#"{{"prompt": "XQZA = 12345. filler. Q XQZA\nA ", "max_new": 4, "id": "l{i}", "tenant": "light"}}"#
        );
        let r = light.request(&req).unwrap();
        assert!(r.get("error").is_none(), "light tenant reply {i}: {r:?}");
        assert!(r.get("text").is_some());
    }

    // drain the flood tenant's streams to completion
    let mut done = 0;
    while done < 5 {
        let ev = flood.recv(std::time::Duration::from_secs(120)).unwrap();
        if ev.get("event").and_then(|e| e.as_str()) == Some("done") {
            assert!(ev.get("error").is_none(), "{ev:?}");
            done += 1;
        }
    }

    let set = srv.shard_set();
    assert!(
        set.tenant_peak_inflight("flood") <= 2,
        "flooding tenant exceeded its in-flight cap: peak {}",
        set.tenant_peak_inflight("flood")
    );
    assert!(set.tenant_peak_inflight("flood") >= 1);
    assert!(set.tenant_peak_inflight("light") <= 2);
    assert!(
        set.throttle_waits() >= 1,
        "5 offered vs cap 2 must park the flooding tenant's submit at least once"
    );
}
