//! Minimal in-repo substitute for the `once_cell` crate, backed by
//! `std::sync::OnceLock` (crates.io is unreachable offline — DESIGN.md
//! §7). API-compatible subset: `sync::OnceCell` and `sync::Lazy`.

pub mod sync {
    use std::sync::OnceLock;

    pub struct OnceCell<T>(OnceLock<T>);

    impl<T> OnceCell<T> {
        pub const fn new() -> OnceCell<T> {
            OnceCell(OnceLock::new())
        }

        pub fn get(&self) -> Option<&T> {
            self.0.get()
        }

        pub fn set(&self, value: T) -> Result<(), T> {
            self.0.set(value)
        }

        pub fn get_or_init<F: FnOnce() -> T>(&self, f: F) -> &T {
            self.0.get_or_init(f)
        }
    }

    impl<T> Default for OnceCell<T> {
        fn default() -> Self {
            Self::new()
        }
    }

    /// Lazily-initialized static value; `F` defaults to a fn pointer so
    /// `static X: Lazy<T> = Lazy::new(init_fn)` works as with once_cell.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init }
        }
    }

    impl<T, F: Fn() -> T> std::ops::Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            self.cell.get_or_init(|| (self.init)())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::{Lazy, OnceCell};

    static GLOBAL: Lazy<u64> = Lazy::new(|| 41 + 1);
    static CELL: OnceCell<String> = OnceCell::new();

    #[test]
    fn lazy_static_derefs() {
        assert_eq!(*GLOBAL, 42);
        assert_eq!(*GLOBAL, 42);
    }

    #[test]
    fn once_cell_init_once() {
        let v = CELL.get_or_init(|| "first".to_string());
        assert_eq!(v, "first");
        assert_eq!(CELL.get_or_init(|| "second".to_string()), "first");
        assert!(CELL.set("third".to_string()).is_err());
        assert_eq!(CELL.get().unwrap(), "first");
    }
}
