//! Minimal in-repo substitute for the `anyhow` crate.
//!
//! Built in-repo because crates.io is unreachable offline (the same
//! DESIGN.md §7 rationale as util::json / util::rng / util::propcheck).
//! API-compatible subset of what this codebase uses: [`Error`],
//! [`Result`], [`anyhow!`], [`bail!`], and the [`Context`] extension
//! trait for `Result` and `Option`. `{e}` prints the outermost message,
//! `{e:#}` the full context chain — matching real anyhow's formatting
//! contract. If network access is available, this can be swapped for
//! crates.io anyhow by editing rust/Cargo.toml; no call sites change.

use std::fmt;

/// A context-chained error value (message + optional cause).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap with an outer context message (innermost cause stays last).
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error { msg: ctx.to_string(), source: Some(Box::new(self)) }
    }

    fn chain_fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = &self.source;
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = &e.source;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // "{:#}" — the whole chain, outermost first
            self.chain_fmt(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    ")?;
            src.chain_fmt(f)?;
        }
        Ok(())
    }
}

// Like real anyhow: Error deliberately does NOT implement std::error::Error,
// which is what makes this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg, source: None }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {x}")`, `anyhow!("fmt {}", x)` or `anyhow!(display_value)`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `bail!(..)` = `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/here/xyz")
            .with_context(|| "reading xyz".to_string())?;
        Ok(s)
    }

    #[test]
    fn context_chain_formats() {
        let e = fails_io().unwrap_err();
        assert_eq!(format!("{e}"), "reading xyz");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading xyz: "), "{full}");
        assert!(full.len() > "reading xyz: ".len());
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn macros_and_option_context() {
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
        let x = 3;
        let e = anyhow!("inline {x}");
        assert_eq!(format!("{e}"), "inline 3");
        let e = anyhow!(String::from("from display"));
        assert_eq!(format!("{e}"), "from display");
        let none: Option<u8> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");

        fn bails(flag: bool) -> Result<u8> {
            if flag {
                bail!("flagged {}", 1);
            }
            Ok(0)
        }
        assert_eq!(format!("{}", bails(true).unwrap_err()), "flagged 1");
        assert_eq!(bails(false).unwrap(), 0);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let v: i32 = "not a number".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }
}
